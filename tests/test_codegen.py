"""RVV codegen: jaxpr -> assembly emission round-tripped through the
decoder.  The property tier fuzzes random well-formed kernel specs through
emit -> decode -> fingerprint comparison at every MVL of the paper grid;
unit tiers pin the emitter's loud-error contract, the malformed-emission
safety net (``isa.validate_trace``), the generated-corpus round trip, and
the ML ``:asm`` variants riding the serving layers."""
import numpy as np
import pytest

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from repro.testing.hypothesis_shim import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import codegen, crossval, dse, engine as eng
from repro.core import frontend as fe
from repro.core import isa, rvv, suite, tracegen
from repro.serve.sim_service import SimService

MVLS = rvv.CHECK_MVLS


# -------------------------------------------------------- fuzz property tier

_FUZZ_MIXES = (
    {"simple": 1.0},
    {"simple": 0.5, "mul": 0.5},
    {"simple": 0.5, "mul": 0.35, "div": 0.05, "trans": 0.10},
    {"simple": 0.3, "mul": 0.3, "div": 0.2, "trans": 0.2},
)


def _random_spec(seed: int):
    """A random *well-formed* kernel spec built from the frontend's public
    primitives: 1-3 input streams (unit or strided), a random-length
    ``chain_ops`` run over a random window, optionally a reduction whose
    result the scalar core consumes (the dep_scalar round trip), and an
    output stream store.  All randomness is drawn up front so the returned
    spec is a pure function of (mvl, cfg), like ``App.kernel``."""
    rng = np.random.RandomState(seed)
    n_streams = int(rng.randint(1, 4))
    patterns = [isa.MEM_UNIT, isa.MEM_UNIT, isa.MEM_STRIDED]
    streams = tuple(
        fe.Stream(f"s{i}", float(rng.choice([8.0, 64.0, 3072.0])),
                  pattern=patterns[rng.randint(3)])
        for i in range(n_streams))
    n_ops = int(rng.randint(4, 20))
    mix = _FUZZ_MIXES[rng.randint(len(_FUZZ_MIXES))]
    window = int((4, 8, 16)[rng.randint(3)])
    seed_streams = bool(rng.randint(2))
    with_reduce = bool(rng.randint(2))
    with_dep = with_reduce and bool(rng.randint(2))
    scalar_work = float(rng.randint(2, 40))
    avl = int(rng.randint(300, 5000))

    def spec(mvl, cfg):
        vl = min(mvl, cfg.mvl) if cfg else mvl

        def fn(*vals):
            seeds = vals if seed_streams else (1.5,)
            win = fe.chain_ops(n_ops, mix, seeds=seeds, vl=vl,
                               window=window)
            r = win[min(3, window - 1)]
            if with_reduce:
                s = jnp.sum(r)          # noqa: F841  scalar core consumes it
            return r

        segs = [fe.KernelBody(fn, vl, ins=streams,
                              outs=(fe.Stream("o", 64.0),))]
        if with_dep:
            segs.append(fe.ScalarWork(scalar_work, dep_scalar=True))
        else:
            segs.append(fe.ScalarWork(scalar_work))
        return segs

    return spec, avl


seeds = st.integers(min_value=0, max_value=10 ** 9)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seeds)
def test_fuzzed_kernels_round_trip_bitwise_at_every_mvl(seed):
    """ISSUE acceptance property: for >= 25 random well-formed kernels,
    ``decode(emit(kernel))`` is bitwise fingerprint-equal to the direct
    jaxpr lowering at every MVL of the paper grid, with the exact
    fractional chunk count and clean trace invariants."""
    spec, avl = _random_spec(seed)
    text = codegen.emit_kernel(spec, f"fuzz{seed}", avl)
    for m in MVLS:
        cfg = eng.VectorEngineConfig(mvl=m, lanes=4)
        d = rvv.decode(text, m, cfg, path=f"<fuzz:{seed}>")
        want = fe.lower(spec(m, cfg)).trace
        assert len(d.trace) == len(want), (seed, m)
        assert isa.trace_fingerprint(d.trace) == \
            isa.trace_fingerprint(want), (seed, m)
        assert d.chunks == avl / m, (seed, m, d.chunks)
        assert d.validate() == [], (seed, m, d.validate())


# ------------------------------------------------- malformed-emission safety

def _saxpy_spec(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    return [fe.KernelBody(lambda x, y: x * 2.0 + y, vl,
                          ins=(fe.Stream("x", 64.0), fe.Stream("y", 64.0)),
                          outs=(fe.Stream("o", 64.0),))]


def test_validate_trace_catches_malformed_emissions():
    """Satellite: the decoder + ``isa.validate_trace`` safety net flags
    emissions whose bodies violate the IR invariants — sources that dangle
    without their prologue definitions, and VLs above the machine MVL."""
    def gather_spec(mvl, cfg):
        vl = min(mvl, cfg.mvl) if cfg else mvl
        return [fe.KernelBody(
            lambda t, y: t + y, vl,
            ins=(fe.Stream("t", 3072.0, pattern=isa.MEM_INDEXED),
                 fe.Stream("y", 64.0)),
            outs=(fe.Stream("o", 64.0),))]

    text = codegen.emit_kernel(gather_spec, "gather", 4096, mvls=(64,))
    d = rvv.decode(text, 64, eng.VectorEngineConfig(mvl=64, lanes=4))
    assert d.validate() == []
    # same body, but claim a smaller machine: the 64-element records violate
    # vl <= mvl
    assert any("vl" in p for p in
               isa.validate_trace(d.trace, 8, predefined=d.prologue_defs))
    # same body, but drop the prologue definitions: the gather's index
    # vector (defined by the prologue vid.v) dangles
    assert isa.validate_trace(d.trace, 64, predefined=frozenset()) != []


def test_corrupted_emission_text_is_loud():
    """Hand-corrupt generated text: the decoder refuses streams whose
    register dataflow no longer closes instead of guessing."""
    text = codegen.emit_kernel(_saxpy_spec, "saxpy", 4096, mvls=(64,))
    # reading a register the corrupted text never writes is loud
    broken = text.replace("vle64.v v0", "vle64.v v9", 1)
    with pytest.raises(rvv.RvvError, match="read before any write"):
        rvv.decode(broken, 64)
    # an undispatched VL reaches the abort trampoline and is loud too
    with pytest.raises(rvv.RvvError, match="not decodable"):
        rvv.decode(text, 128, eng.VectorEngineConfig(mvl=128, lanes=4))


def test_emitter_rejects_unspellable_records():
    def mk(**kw):
        rec = dict(kind=isa.VARITH, vl=8, fu=isa.FU_SIMPLE, n_src=2,
                   src1=1, src2=2, dst=3, mem_pattern=0,
                   footprint_kb=0.0, scalar_count=0, dep_scalar=False)
        rec.update(kw)
        return rec
    emit1 = lambda recs: codegen.emit("t", {8: recs}, {8: 1.0}, {8: 8})
    with pytest.raises(codegen.CodegenError, match="no scalar spelling"):
        emit1([mk(kind=isa.SCALAR_BLOCK, vl=0, fu=isa.FU_TRANS, n_src=0,
                  src1=-1, src2=-1, dst=-1, scalar_count=4)])
    with pytest.raises(codegen.CodegenError, match="coalesce"):
        emit1([mk(kind=isa.SCALAR_BLOCK, vl=0, n_src=0, src1=-1, src2=-1,
                  dst=-1, scalar_count=4),
               mk(kind=isa.SCALAR_BLOCK, vl=0, n_src=0, src1=-1, src2=-1,
                  dst=-1, scalar_count=4)])
    with pytest.raises(codegen.CodegenError, match="FU_SIMPLE"):
        emit1([mk(), mk(kind=isa.VREDUCE, fu=isa.FU_MUL, n_src=1, src1=3,
                        src2=-1, dst=4)])
    with pytest.raises(codegen.CodegenError, match="NOP"):
        emit1([mk(), mk(kind=isa.NOP, vl=0, n_src=0, src1=-1, src2=-1,
                        dst=-1)])


# --------------------------------------------------- generated-corpus gate

def test_generated_corpus_round_trips():
    """ISSUE acceptance (test-tier half; ci.sh --check-all runs the full
    grid): every app with a kernel= spec round-trips emit -> decode ->
    fingerprint-equal to the jaxpr lowering, with the characterized chunk
    count, at the grid's extremes."""
    reports = crossval.round_trip_all(mvls=(8, 256))
    assert {r.app for r in reports} == \
        {a for a in tracegen.APPS if tracegen.APPS[a].kernel is not None}
    assert len({r.app for r in reports}) == 10
    bad = [(r.app, r.mvl, r.problems) for r in reports if not r.ok]
    assert not bad, bad


def test_emitted_app_matches_checked_in_corpus():
    """The committed .s files are exactly what the emitter produces (the
    ci.sh corpus-drift gate pins all ten; one here keeps the contract in
    the test tier)."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                        "asm", "blackscholes.s")
    with open(path) as f:
        assert f.read() == codegen.emit_app("blackscholes")


# ------------------------------------------- ML :asm variants in the layers

def test_ml_asm_variants_ride_dse_explore():
    sp = dse.DesignSpace.of("t", mvl=(16, 64), lanes=(4,))
    res = dse.explore(sp, apps=("flash_attention:asm", "ssd_scan:asm"))
    assert len(res.records) == 4
    for r in res.records:
        base = r.app.removesuffix(":asm")
        want = suite.speedup(base, r.cfg)
        # bitwise-identical body + identical chunk model -> same speedup
        assert abs(r.speedup - want) <= 1e-5 * want, (r.app, r.cfg)


def test_ml_asm_variants_ride_sim_service():
    svc = SimService()
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    svc.submit("decode_attention:asm", cfg)
    svc.submit("decode_attention", cfg)
    svc.drain()
    by_app = {r.app: r for r in svc.completed}
    asm, direct = by_app["decode_attention:asm"], by_app["decode_attention"]
    assert asm.steady_ns == direct.steady_ns
    assert asm.runtime_ns == direct.runtime_ns
