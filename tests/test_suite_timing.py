"""Timing-model regression vs the paper's §5 speedup anchors, plus the
scalar-pipeline model's unit tier (event accounting, knob monotonicity,
batched bitwise equivalence)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import scalar_pipeline as sp
from repro.core import suite, tracegen
from repro.core.anchors import ANCHORS, EQ_HI, EQ_LO, LT_SLACK


@pytest.mark.parametrize("app,mvl,lanes,target,kind", ANCHORS)
def test_anchor_speedups(app, mvl, lanes, target, kind):
    """All 11 §5 anchors within the documented tolerance (the scorecard's
    contract, tier-1 enforced)."""
    got = suite.speedup(app, eng.VectorEngineConfig(mvl=mvl, lanes=lanes))
    if kind == "eq":
        assert EQ_LO <= got / target <= EQ_HI, (app, got, target)
    else:
        assert got <= target * LT_SLACK, (app, got, target)


# ------------------------------------------------- scalar-pipeline unit tier

def _cycles(seg, cfg=None):
    cyc, _ = sp._pipeline_jit(jnp.asarray(np.asarray(seg, np.float32)),
                              tuple(jnp.asarray(p)
                                    for p in sp.cfg_scalar_params(cfg)))
    return float(cyc)


def test_raw_chain_latency():
    """A fully dependent chain of lat-4 ops: every instruction pays the
    producer's remaining 3 cycles on top of its issue slot."""
    #       count   lat  raw  fus  bmr  mem  isbr struct
    seg = [[1024.0, 4.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]]
    assert _cycles(seg) == 1024.0 / 2 + 1024.0 * 3
    # an independent stream of the same ops is issue-bound only
    seg[0][2] = 0.0
    assert _cycles(seg) == 1024.0 / 2


def test_issue_width_monotonic():
    for app in sorted(tracegen.APPS):
        t = {w: sp.scalar_runtime_ns(app,
                                     eng.VectorEngineConfig(issue_width=w))
             for w in (1, 2, 4)}
        assert t[1] > t[2] >= t[4], (app, t)


def test_branch_penalty_monotonic():
    for app in ("canneal", "pathfinder"):       # branchy profiles
        t = {p: sp.scalar_runtime_ns(
                 app, eng.VectorEngineConfig(branch_miss_penalty=p))
             for p in (2.0, 6.0, 20.0)}
        assert t[2.0] < t[6.0] < t[20.0], (app, t)


def test_fusion_saves_issue_slots():
    for app in sorted(tracegen.APPS):
        assert sp.scalar_runtime_ns(
            app, eng.VectorEngineConfig(fusion=True)) \
            < sp.scalar_runtime_ns(app), app


def test_batched_matches_sequential_bitwise():
    apps = sorted(tracegen.APPS)
    cfgs = [eng.VectorEngineConfig(issue_width=1 + i % 3,
                                   branch_miss_penalty=float(4 + 2 * (i % 4)),
                                   fusion=bool(i % 2))
            for i in range(len(apps))]
    assert sp.scalar_runtime_ns_batch(apps, cfgs) == \
        [sp.scalar_runtime_ns(a, c) for a, c in zip(apps, cfgs)]


def test_implied_cpi_is_physical():
    """Acceptance: no app's scalar baseline implies CPI < 0.5 (the old
    particlefilter 0.104 multiplier implied ~5 IPC on a dual-issue core)."""
    for app in sorted(tracegen.APPS):
        prof = tracegen.scalar_profile_for(app)
        n = tracegen.app_for(app).counts(8).scalar_code_total \
            * prof.roi_instr_fraction
        assert sp.scalar_cycles(app) / n >= 0.5, app


def test_event_breakdown_sums_to_cycles():
    """The per-kind accumulators decompose the total exactly (bmiss counts
    scale by the penalty; bhit/fused are counts, not cycles)."""
    cfg = eng.VectorEngineConfig(fusion=True)
    for app in ("blackscholes", "particlefilter"):
        ev = sp.scalar_events(app, cfg)
        total = (ev["issue"] + ev["raw"] + ev["struct"]
                 + ev["bmiss"] * cfg.branch_miss_penalty + ev["mem"])
        assert np.isclose(total, sp.scalar_cycles(app, cfg), rtol=1e-6), app


# --------------------------------------- residual-derivation MVL consistency

def test_streamcluster_mvl256_residual_uses_effective_mvl():
    """Regression (ISSUE-9 satellite): vector_runtime_from_per_chunk derived
    its residual from counts(cfg.mvl) while body/chunks clamp to the app's
    max_vl — at streamcluster@mvl=256 (max_vl=128) the derivation must be
    identical to mvl=128's."""
    c128 = eng.VectorEngineConfig(mvl=128, lanes=4)
    c256 = eng.VectorEngineConfig(mvl=256, lanes=4)
    body = tracegen.body_for("streamcluster", 128, c128)
    per_chunk = eng.steady_state_time(body, c128)
    assert suite.vector_runtime_from_per_chunk(
        "streamcluster", c256, body, per_chunk) == \
        suite.vector_runtime_from_per_chunk(
            "streamcluster", c128, body, per_chunk)
    assert suite.vector_runtime_ns("streamcluster", c256) == \
        suite.vector_runtime_ns("streamcluster", c128)


def test_residual_derivation_clamps_counts_numerically():
    """Same contract, numerically forced: a synthetic app whose residual
    scalar count GROWS with MVL would inflate the mvl=256 runtime if the
    derivation ever read counts(cfg.mvl) again instead of the effective
    (clamped) MVL."""
    def counts(mvl):
        return tracegen.Counts(scalar_code_total=2e6, scalar_instrs=1e3 * mvl,
                               vector_mem=10.0, vector_arith=10.0,
                               vector_ops=1e5)
    synth = dataclasses.replace(
        tracegen.APPS["streamcluster"], name="synth_clamp", counts=counts,
        chunks=lambda mvl: 4.0, max_vl=128)
    tracegen.APPS["synth_clamp"] = synth
    try:
        c128 = eng.VectorEngineConfig(mvl=128, lanes=4)
        c256 = eng.VectorEngineConfig(mvl=256, lanes=4)
        body = tracegen.body_for("synth_clamp", 128, c128)
        rt = {c.mvl: suite.vector_runtime_from_per_chunk(
                  "synth_clamp", c, body, 100.0) for c in (c128, c256)}
        assert rt[256] == rt[128]
        # the un-clamped derivation would differ by the extra residual
        extra = (counts(256).scalar_instrs - counts(128).scalar_instrs)
        assert extra * eng.SCALAR_CYCLES[0] * 0.25 > 1e4  # bug would be loud
    finally:
        del tracegen.APPS["synth_clamp"]


def test_canneal_degrades_at_large_mvl():
    """Paper §5.2: MVL>=128 is slower than scalar for canneal."""
    for mvl in (128, 256):
        got = suite.speedup("canneal", eng.VectorEngineConfig(mvl=mvl, lanes=1))
        assert got < 1.0, (mvl, got)


def test_canneal_best_at_short_mvl():
    s = {m: suite.speedup("canneal", eng.VectorEngineConfig(mvl=m, lanes=1))
         for m in (8, 16, 64, 256)}
    assert max(s, key=s.get) in (8, 16)
    assert s[16] > s[256]


def test_particlefilter_never_beats_scalar():
    """Paper §5.4: no PF configuration beats the scalar core."""
    for mvl in (8, 64, 256):
        for lanes in (1, 8):
            got = suite.speedup(
                "particlefilter", eng.VectorEngineConfig(mvl=mvl, lanes=lanes))
            assert got <= 1.0, (mvl, lanes, got)


def test_lane_scaling_regimes():
    """Paper §5.1/5.3: lanes help large-MVL configs much more than short-MVL."""
    for app in ("blackscholes", "jacobi-2d"):
        s8_1 = suite.speedup(app, eng.VectorEngineConfig(mvl=8, lanes=1))
        s8_8 = suite.speedup(app, eng.VectorEngineConfig(mvl=8, lanes=8))
        s256_1 = suite.speedup(app, eng.VectorEngineConfig(mvl=256, lanes=1))
        s256_8 = suite.speedup(app, eng.VectorEngineConfig(mvl=256, lanes=8))
        assert (s256_8 / s256_1) > (s8_8 / s8_1), app
        assert s256_8 / s256_1 > 2.0, app       # near-linear at large MVL


def test_swaptions_llc_study():
    """Paper §5.7 / Fig 10: with a 256 KB L2 the speedup degrades at large
    MVL; a 1 MB L2 keeps improving through MVL=256."""
    small = {m: suite.speedup("swaptions",
                              eng.VectorEngineConfig(mvl=m, lanes=8, l2_kb=256))
             for m in (64, 128, 256)}
    big = {m: suite.speedup("swaptions",
                            eng.VectorEngineConfig(mvl=m, lanes=8, l2_kb=1024))
           for m in (64, 128, 256)}
    assert big[256] > small[256]
    assert big[256] >= big[64]


def test_streamcluster_memory_bound():
    """Paper §5.6: lane scaling is weak (memory bound)."""
    s1 = suite.speedup("streamcluster", eng.VectorEngineConfig(mvl=64, lanes=1))
    s8 = suite.speedup("streamcluster", eng.VectorEngineConfig(mvl=64, lanes=8))
    assert s8 / s1 < 2.5
