"""Timing-model regression vs the paper's §5 speedup anchors (calibrated)."""
import pytest

from repro.core import engine as eng
from repro.core import suite

# (app, mvl, lanes, paper value, tolerance-in-log-space)
EXACT = [
    ("blackscholes", 8, 1, 2.22),
    ("jacobi-2d", 8, 1, 1.79),
    ("jacobi-2d", 256, 1, 2.99),
    ("canneal", 16, 1, 1.64),
    ("canneal", 16, 8, 1.88),
    ("pathfinder", 8, 1, 1.8),
    ("streamcluster", 8, 1, 1.68),
    ("swaptions", 8, 1, 1.03),
]


@pytest.mark.parametrize("app,mvl,lanes,target", EXACT)
def test_anchor_speedups(app, mvl, lanes, target):
    got = suite.speedup(app, eng.VectorEngineConfig(mvl=mvl, lanes=lanes))
    assert 0.80 <= got / target <= 1.25, (app, got, target)


def test_canneal_degrades_at_large_mvl():
    """Paper §5.2: MVL>=128 is slower than scalar for canneal."""
    for mvl in (128, 256):
        got = suite.speedup("canneal", eng.VectorEngineConfig(mvl=mvl, lanes=1))
        assert got < 1.0, (mvl, got)


def test_canneal_best_at_short_mvl():
    s = {m: suite.speedup("canneal", eng.VectorEngineConfig(mvl=m, lanes=1))
         for m in (8, 16, 64, 256)}
    assert max(s, key=s.get) in (8, 16)
    assert s[16] > s[256]


def test_particlefilter_never_beats_scalar():
    """Paper §5.4: no PF configuration beats the scalar core."""
    for mvl in (8, 64, 256):
        for lanes in (1, 8):
            got = suite.speedup(
                "particlefilter", eng.VectorEngineConfig(mvl=mvl, lanes=lanes))
            assert got <= 1.0, (mvl, lanes, got)


def test_lane_scaling_regimes():
    """Paper §5.1/5.3: lanes help large-MVL configs much more than short-MVL."""
    for app in ("blackscholes", "jacobi-2d"):
        s8_1 = suite.speedup(app, eng.VectorEngineConfig(mvl=8, lanes=1))
        s8_8 = suite.speedup(app, eng.VectorEngineConfig(mvl=8, lanes=8))
        s256_1 = suite.speedup(app, eng.VectorEngineConfig(mvl=256, lanes=1))
        s256_8 = suite.speedup(app, eng.VectorEngineConfig(mvl=256, lanes=8))
        assert (s256_8 / s256_1) > (s8_8 / s8_1), app
        assert s256_8 / s256_1 > 2.0, app       # near-linear at large MVL


def test_swaptions_llc_study():
    """Paper §5.7 / Fig 10: with a 256 KB L2 the speedup degrades at large
    MVL; a 1 MB L2 keeps improving through MVL=256."""
    small = {m: suite.speedup("swaptions",
                              eng.VectorEngineConfig(mvl=m, lanes=8, l2_kb=256))
             for m in (64, 128, 256)}
    big = {m: suite.speedup("swaptions",
                            eng.VectorEngineConfig(mvl=m, lanes=8, l2_kb=1024))
           for m in (64, 128, 256)}
    assert big[256] > small[256]
    assert big[256] >= big[64]


def test_streamcluster_memory_bound():
    """Paper §5.6: lane scaling is weak (memory bound)."""
    s1 = suite.speedup("streamcluster", eng.VectorEngineConfig(mvl=64, lanes=1))
    s8 = suite.speedup("streamcluster", eng.VectorEngineConfig(mvl=64, lanes=8))
    assert s8 / s1 < 2.5
