"""DSE subsystem tests: spaces, cache/dedup, Pareto reductions, sharding.

The expensive end-to-end behavior (384-point sweep, repeat-run cache hits)
lives in ``benchmarks/run.py --dse`` and the ``scripts/ci.sh`` dse-smoke
gate; here the spaces are kept tiny so the suite stays fast.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import dse
from repro.core import engine as eng
from repro.core import suite
from repro.configs import vector_engine as vcfg


# ------------------------------------------------------------- DesignSpace

def test_design_space_size_and_enumeration_order():
    sp = dse.DesignSpace.of("t", mvl=(8, 64), lanes=(1, 4), mshrs=(1, 16))
    assert sp.size() == 8
    cfgs = sp.configs()
    assert len(cfgs) == 8
    # last axis fastest, and config_at agrees with configs()
    assert (cfgs[0].mvl, cfgs[0].lanes, cfgs[0].mshrs) == (8, 1, 1)
    assert (cfgs[1].mvl, cfgs[1].lanes, cfgs[1].mshrs) == (8, 1, 16)
    assert (cfgs[-1].mvl, cfgs[-1].lanes, cfgs[-1].mshrs) == (64, 4, 16)
    for i, c in enumerate(cfgs):
        assert sp.config_at(i) == c


def test_design_space_validates_fields_and_choices():
    with pytest.raises(ValueError, match="unknown"):
        dse.DesignSpace.of("bad", not_a_knob=(1, 2))
    with pytest.raises(ValueError, match="no choices"):
        dse.DesignSpace.of("bad", mvl=())
    with pytest.raises(IndexError):
        dse.DesignSpace.of("t", mvl=(8, 64)).config_at(2)


def test_design_space_sampling_is_deterministic_and_distinct():
    sp = vcfg.SPACE_FULL
    a = sp.sample(50, seed=3)
    b = sp.sample(50, seed=3)
    c = sp.sample(50, seed=4)
    assert a == b
    assert a != c
    assert len({cfg.label() for cfg in a}) == 50
    # n == size returns the full enumeration
    tiny = dse.DesignSpace.of("t", mvl=(8, 64))
    assert tiny.sample(2) == tiny.configs()


def test_design_space_sample_seed_pin():
    """The search loop depends on seeded sampling never drifting: pin the
    exact configs sample(seed=7) picks today (ISSUE-8 satellite)."""
    sp = dse.DesignSpace.of("pin", mvl=(8, 64, 256), lanes=(1, 4),
                            mshrs=(1, 16))
    picked = [(c.mvl, c.lanes, c.mshrs) for c in sp.sample(4, seed=7)]
    assert picked == [(8, 4, 1), (64, 1, 16), (64, 4, 16), (256, 4, 1)]
    # sorted flat indices: the sample preserves enumeration order
    flat = [sp.configs().index(c) for c in sp.sample(4, seed=7)]
    assert flat == sorted(flat)


def test_design_space_sample_rejects_oversampling():
    """n > size() must raise, not silently duplicate or shrink — a caller
    believing it explored n points must actually have n distinct configs."""
    tiny = dse.DesignSpace.of("t", mvl=(8, 64))
    with pytest.raises(ValueError, match="sample\\(10\\).*only 2"):
        tiny.sample(10)


def test_space_presets_have_documented_sizes():
    assert vcfg.SPACE_SMOKE.size() == 64
    assert vcfg.SPACE_QUICK.size() == 384
    assert vcfg.SPACE_FULL.size() == 1536
    # every axis is a real config field; the spaces construct cleanly
    assert len(vcfg.SPACE_FULL.configs()) == 1536


def test_labels_unique_over_space_full():
    """ISSUE satellite: the result-cache/result keys over the full DSE space
    (incl. the dram_bw_bytes_cycle axis) must never alias."""
    cfgs = vcfg.SPACE_FULL.configs()
    labels = [c.label() for c in cfgs]
    assert len(set(labels)) == len(cfgs)
    # the DRAM-bandwidth axis specifically is keyed
    base = eng.VectorEngineConfig(mvl=64, lanes=4, dram_bw_bytes_cycle=8.0)
    assert "dram_bw" in base.label()
    # float knobs that %g would round together stay distinct
    a = eng.VectorEngineConfig(dram_bw_bytes_cycle=4.0000001)
    b = eng.VectorEngineConfig(dram_bw_bytes_cycle=4.0000002)
    assert a.label() != b.label()


def test_labels_unique_over_scalar_knob_extension():
    """ISSUE-9 satellite: the space extended by the scalar-core knobs
    (issue_width / branch_miss_penalty / fusion) must keep labels unique —
    the PR-4 float-aliasing bug showed silent key collisions are real."""
    import dataclasses
    base = vcfg.SPACE_FULL.configs()[:64]
    extended = list(base)
    for cfg in base:
        extended += [dataclasses.replace(cfg, issue_width=1),
                     dataclasses.replace(cfg, branch_miss_penalty=12.0),
                     dataclasses.replace(cfg, fusion=True)]
    labels = [c.label() for c in extended]
    assert len(set(labels)) == len(extended)
    assert "_fusion" in eng.VectorEngineConfig(fusion=True).label()


def test_config_fingerprint_distinguishes_scalar_knobs():
    """The new knobs change the vector engine's scalar-block timing, so
    they MUST enter config_fingerprint — a stale cache hit across them
    would silently serve the wrong per-chunk time."""
    import dataclasses
    base = eng.VectorEngineConfig(mvl=64, lanes=4)
    fps = {eng.config_fingerprint(base)}
    for up in (dict(issue_width=1), dict(issue_width=4),
               dict(branch_miss_penalty=12.0), dict(fusion=True)):
        fps.add(eng.config_fingerprint(dataclasses.replace(base, **up)))
    assert len(fps) == 5


def test_cache_misses_on_new_scalar_knob():
    """End-to-end: a cache warmed at the default scalar core must MISS (and
    re-simulate) when a scalar knob changes, not serve the stale cell."""
    import dataclasses
    cache = dse.ResultCache()
    sp1 = dse.DesignSpace.of("t_iw", mvl=(16,), lanes=(2,))
    r1 = dse.explore(sp1, apps=("blackscholes",), cache=cache)
    assert r1.stats["simulated"] == 1
    cfg_f = dataclasses.replace(sp1.configs()[0], fusion=True)
    r2 = dse.explore([cfg_f], apps=("blackscholes",), cache=cache)
    assert r2.stats["simulated"] == 1      # miss: fusion is its own cell
    _, k1 = dse.cell_key("blackscholes", sp1.configs()[0], 8, 24)
    _, k2 = dse.cell_key("blackscholes", cfg_f, 8, 24)
    assert k1 != k2
    # the scalar side sees the knob too: same vector cell, new baseline
    assert r2.records[0].speedup != r1.records[0].speedup


# ----------------------------------------------------------- area/cost proxy

def test_area_proxy_monotone_in_capability():
    base = eng.VectorEngineConfig(mvl=64, lanes=4)
    for up in (dict(mvl=256), dict(lanes=8), dict(phys_regs=64),
               dict(l2_kb=1024), dict(mshrs=64), dict(l1_kb=64)):
        import dataclasses
        bigger = dataclasses.replace(base, **up)
        assert dse.area_proxy_kb(bigger) > dse.area_proxy_kb(base), up


# ------------------------------------------------------------- ResultCache

def test_result_cache_roundtrip_and_stats(tmp_path):
    path = str(tmp_path / "cache.jsonl")
    c = dse.ResultCache(path)
    assert c.get("k1") is None and c.misses == 1
    c.put("k1", 1.25)
    c.flush()
    assert c.get("k1") == 1.25 and c.hits == 1
    # a fresh object re-reads the file: persistence at full float precision
    c2 = dse.ResultCache(path)
    assert len(c2) == 1 and c2.get("k1") == 1.25
    c2.put("k2", 3.0000000000000004)
    c2.flush()
    assert dse.ResultCache(path).get("k2") == 3.0000000000000004


def test_result_cache_skips_corrupt_trailing_line(tmp_path):
    """A process killed mid-append leaves a truncated trailing JSONL line;
    loading must skip it with a warning, not crash (the PR-6 crash-safety
    regression)."""
    import warnings
    path = str(tmp_path / "cache.jsonl")
    c = dse.ResultCache(path)
    c.put("k1", 1.5)
    c.put("k2", 2.5)
    c.flush()
    with open(path, "a") as f:
        f.write('{"k": "k3", "v": 3.')      # truncated mid-flush
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c2 = dse.ResultCache(path)
    assert any("malformed" in str(x.message) for x in w)
    assert c2.corrupt_lines == 1
    assert len(c2) == 2                     # intact records survive
    assert c2.get("k1") == 1.5 and c2.get("k2") == 2.5
    # appending after recovery still round-trips
    c2.put("k4", 4.5)
    c2.flush()
    assert dse.ResultCache(path).get("k4") == 4.5


def test_result_cache_tolerates_non_record_lines(tmp_path):
    path = str(tmp_path / "cache.jsonl")
    with open(path, "w") as f:
        f.write('{"not_k": 1}\n')           # valid JSON, wrong schema
        f.write('[1, 2, 3]\n')              # not an object
        f.write('{"k": "good", "v": 7.0}\n')
    import warnings
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        c = dse.ResultCache(path)
    assert c.corrupt_lines == 2 and c.get("good") == 7.0


def test_result_cache_concurrent_flush_never_interleaves(tmp_path):
    """Many writers appending concurrently to one JSONL: every line must
    still parse and every record must survive (the single locked O_APPEND
    write contract)."""
    from concurrent.futures import ThreadPoolExecutor
    path = str(tmp_path / "cache.jsonl")
    n_writers, n_each = 8, 50

    def writer(w):
        c = dse.ResultCache(path)
        for i in range(n_each):
            # long-ish keys make torn writes visible if they ever happen
            c.put(f"writer{w}_rec{i}_" + "x" * 64, float(w * 1000 + i))
            if i % 7 == 0:
                c.flush()
        c.flush()

    with ThreadPoolExecutor(n_writers) as ex:
        list(ex.map(writer, range(n_writers)))

    merged = dse.ResultCache(path)
    assert merged.corrupt_lines == 0
    assert len(merged) == n_writers * n_each
    for w in range(n_writers):
        for i in range(n_each):
            assert merged.get(f"writer{w}_rec{i}_" + "x" * 64) == float(
                w * 1000 + i)


def test_result_cache_records_iterates_without_stats():
    c = dse.ResultCache()
    c.put("a", 1.0)
    c.put("b", 2.0)
    h, m = c.hits, c.misses
    assert list(c.records()) == [("a", 1.0), ("b", 2.0)]
    assert (c.hits, c.misses) == (h, m)      # pure read


def test_export_training_rows_joins_cache_to_cells_bitwise():
    """ISSUE-8 satellite: the cache's opaque-keyed values join back to
    (app, config) rows without re-simulating, and the derived runtime is
    bitwise-equal to the DseRecord explore() produced."""
    cache = dse.ResultCache()
    res = dse.explore(SP_TINY, apps=("blackscholes", "canneal"), cache=cache)
    sims = res.stats["simulated"]
    rows = cache.export_training_rows(("blackscholes", "canneal"), SP_TINY)
    assert len(rows) == len(res.records) == 16
    want = {(r.app, r.label): r for r in res.records}
    for row in rows:
        rec = want[(row["app"], row["label"])]
        assert row["steady_ns"] == rec.steady_ns
        assert row["runtime_ns"] == rec.runtime_ns
        assert row["speedup"] == rec.speedup
        assert row["area_kb"] == rec.area_kb
        assert row["cfg"] == rec.cfg
    # the join is a pure read: nothing new was simulated, no stats motion
    h, m = cache.hits, cache.misses
    cache.export_training_rows(("blackscholes",), SP_TINY)
    assert (cache.hits, cache.misses) == (h, m)
    assert dse.explore(SP_TINY, apps=("blackscholes", "canneal"),
                       cache=cache).stats["simulated"] == 0
    assert sims == 16


def test_export_training_rows_skips_unlabeled_cells():
    cache = dse.ResultCache()
    dse.explore(SP_TINY, apps=("blackscholes",), cache=cache)
    # canneal was never explored -> no rows for it, no invention
    rows = cache.export_training_rows(("canneal",), SP_TINY)
    assert rows == []
    # a config list (not a DesignSpace) works too
    rows = cache.export_training_rows(("blackscholes",),
                                      SP_TINY.configs()[:3])
    assert len(rows) == 3


def test_cell_key_matches_result_cache_key():
    """dse.cell_key (the serve layer's entry point) and ResultCache.key (the
    documented contract) must produce the same key for the same cell."""
    from repro.core import suite, tracegen
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    body, key = dse.cell_key("blackscholes", cfg, 8, 24)
    eff = suite.effective_mvl("blackscholes", cfg)
    ref_body = tracegen.body_for("blackscholes", eff, cfg)
    assert key == dse.ResultCache.key(ref_body, cfg, 8, 24)
    assert len(body) == len(ref_body)


def test_cache_key_separates_workloads_and_configs():
    from repro.core import tracegen
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    b1 = tracegen.body_for("blackscholes", 64, cfg)
    b2 = tracegen.body_for("canneal", 64, cfg)
    k = dse.ResultCache.key
    assert k(b1, cfg, 8, 24) != k(b2, cfg, 8, 24)
    assert k(b1, cfg, 8, 24) != k(b1, cfg, 4, 24)
    cfg2 = eng.VectorEngineConfig(mvl=64, lanes=8)
    assert k(b1, cfg, 8, 24) != k(b1, cfg2, 8, 24)


# ----------------------------------------------------------------- explore

SP_TINY = dse.DesignSpace.of("tiny", mvl=(16, 64), lanes=(2, 8),
                             l2_kb=(256, 1024))


def test_explore_matches_suite_speedup():
    res = dse.explore(SP_TINY, apps=("blackscholes",))
    assert len(res.records) == 8
    for r in res.records[:2]:
        want = suite.speedup("blackscholes", r.cfg)
        assert abs(r.speedup - want) <= 1e-5 * want


def test_explore_repeat_is_bitwise_and_fully_cached(tmp_path):
    path = str(tmp_path / "c.jsonl")
    r1 = dse.explore(SP_TINY, apps=("blackscholes", "canneal"),
                     cache=dse.ResultCache(path))
    assert r1.stats["simulated"] == 16 and r1.stats["hit_rate"] == 0.0
    r2 = dse.explore(SP_TINY, apps=("blackscholes", "canneal"),
                     cache=dse.ResultCache(path))
    assert r2.stats["simulated"] == 0 and r2.stats["hit_rate"] == 1.0
    assert [ (a.label, a.steady_ns, a.runtime_ns, a.speedup, a.area_kb)
             for a in r1.records ] == \
           [ (a.label, a.steady_ns, a.runtime_ns, a.speedup, a.area_kb)
             for a in r2.records ]
    assert dse._frontier_fingerprint(r1) == dse._frontier_fingerprint(r2)


def test_explore_dedups_mvl_aliases_within_a_run():
    """streamcluster caps at max_vl=128: mvl=128 and mvl=256 induce the same
    clamped body AND the same timing parameters, so the cache dedups them to
    one dispatch and the records agree exactly."""
    sp = dse.DesignSpace.of("alias", mvl=(128, 256), lanes=(4,))
    res = dse.explore(sp, apps=("streamcluster",))
    assert res.stats["in_run_dedup"] == 1
    assert res.stats["simulated"] == 1
    r128, r256 = res.records
    assert r128.steady_ns == r256.steady_ns
    assert r128.label != r256.label          # results still keyed apart


# -------------------------------------------------- reductions: Pareto etc.

def _rec(app, label, runtime, area):
    return dse.DseRecord(app=app, label=label, cfg=None, steady_ns=runtime,
                         runtime_ns=runtime, speedup=1.0, area_kb=area)


def test_pareto_frontier_drops_dominated_points():
    recs = [_rec("a", "slow_small", 10.0, 1.0),
            _rec("a", "fast_big", 1.0, 10.0),
            _rec("a", "dominated", 10.0, 10.0),
            _rec("a", "mid", 5.0, 5.0),
            _rec("a", "mid_dup", 5.0, 5.0)]   # tie resolves by label
    labels = [r.label for r in dse.pareto_frontier(recs)]
    assert labels == ["fast_big", "mid", "slow_small"]


def test_best_under_budget():
    recs = [_rec("a", "fast_big", 1.0, 10.0),
            _rec("a", "mid", 5.0, 5.0),
            _rec("a", "slow_small", 10.0, 1.0)]
    assert dse.best_under_budget(recs, 100.0).label == "fast_big"
    assert dse.best_under_budget(recs, 6.0).label == "mid"
    assert dse.best_under_budget(recs, 0.5) is None


def test_explored_frontier_is_nondominated_and_summary_serializes():
    res = dse.explore(SP_TINY, apps=("canneal",))
    frontier = res.frontiers()["canneal"]
    assert frontier
    for i, r in enumerate(frontier):
        for s in frontier[i + 1:]:   # sorted: runtime up, area strictly down
            assert s.runtime_ns >= r.runtime_ns and s.area_kb < r.area_kb
        for other in res.records:    # nothing dominates a frontier point
            assert not (other.runtime_ns < r.runtime_ns
                        and other.area_kb < r.area_kb
                        and other.app == r.app)
    js = json.dumps(dse.frontier_summary(res, budgets=(256.0,)))
    assert "canneal" in js


def test_suite_entry_points():
    res = suite.dse_explore(SP_TINY, apps=("blackscholes",))
    assert res.n_configs == 8
    best = suite.dse_best_under_budget(SP_TINY, 1e9, apps=("blackscholes",))
    assert best["blackscholes"] is not None
    assert best["blackscholes"].runtime_ns == min(
        r.runtime_ns for r in res.records)


# ------------------------------------------------------ sharded dispatch

_SHARD_SCRIPT = r"""
import jax
assert jax.local_device_count() == 4, jax.local_device_count()
from repro.core import engine as eng, tracegen
cfg0 = eng.VectorEngineConfig(mvl=64, lanes=4)
tr = tracegen.body_for("blackscholes", 64, cfg0).tile(2)
cfgs = [eng.VectorEngineConfig(mvl=m, lanes=l)
        for m in (8, 64, 128, 256) for l in (2, 8)]
rows = eng.simulate_batch([tr], cfgs)
assert eng._SHARDED_JITS, "sharded path never engaged"
for c, r in zip(cfgs, rows):
    w = eng.simulate(tr, c)
    for k in w:
        assert abs(r[k] - w[k]) <= 1e-5 * max(abs(w[k]), 1.0), (c.label(), k)
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_dispatch_matches_sequential_subprocess():
    """The DSE sharding contract: with >1 device the config axis runs
    through shard_map and results equal the sequential path.  Forced host
    devices need a fresh process (XLA flags are read at jax import)."""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"),
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          cwd=os.path.join(os.path.dirname(__file__), ".."),
                          env=env, capture_output=True, text=True,
                          timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED_OK" in proc.stdout


def test_single_device_fallback_never_builds_sharded_jit():
    """On one device (the default CI environment) every dispatch takes the
    chunked single-device path — the fallback half of the contract."""
    import jax
    if jax.local_device_count() != 1:
        pytest.skip("multi-device environment")
    dse.explore(dse.DesignSpace.of("t1", mvl=(16,), lanes=(2, 4)),
                apps=("pathfinder",))
    assert eng._SHARDED_JITS == {}
