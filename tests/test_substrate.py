"""Optimizer, data pipeline, checkpoint/restart, compression, sharding rules."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing.hypothesis_shim import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import SHAPES, InputShape
from repro.data import pipeline as dpipe
from repro.distributed import compression
from repro.distributed.sharding import LOGICAL_RULES, logical_to_spec
from repro.models import build
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt


# ---- optimizer --------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = opt.init(params)
    cfg = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = opt.apply(cfg, params, g, state)
    assert loss(params) < l0 * 0.05


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    cfg = opt.OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, metrics = opt.apply(cfg, params, g, state)
    assert metrics["grad_norm"] > 99


def test_lr_schedule_shape():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(opt.lr_at(cfg, jnp.int32(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] < lrs[1] < lrs[2]            # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]          # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-5            # floor


# ---- data -------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    cfg = dpipe.DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    b1 = dpipe.batch_at(cfg, 7)
    b2 = dpipe.batch_at(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = dpipe.batch_at(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    full1 = np.concatenate([np.asarray(b1["tokens"]),
                            np.asarray(b1["labels"][:, -1:])], 1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])
    assert int(b1["tokens"].max()) < 100


# ---- checkpoint / restart ---------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen2.5-3b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    state = opt.init(params)
    d = str(tmp_path / "ck")
    ckpt.save(d, 42, params, state)
    assert ckpt.latest_step(d) == 42
    p2, s2, manifest = ckpt.restore(d, 42, params, state)
    assert manifest["step"] == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_resume_and_retry(tmp_path):
    from repro.train.loop import LoopConfig, train
    cfg = get_config("qwen2.5-3b").smoke()
    model = build(cfg)
    shape = InputShape("tiny", 16, 4, "train")
    lc = LoopConfig(total_steps=4, ckpt_every=2, ckpt_dir=str(tmp_path / "ck"),
                    log_every=100)
    st1 = train(model, shape, None, loop_cfg=lc)
    assert st1.step == 4 and all(np.isfinite(st1.losses))
    # resume: raise total steps; loop must restart from step 4
    lc2 = LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=str(tmp_path / "ck"),
                     log_every=100)
    st2 = train(model, shape, None, loop_cfg=lc2)
    assert st2.step == 6 and st2.restarts >= 1 and len(st2.losses) == 2

    # transient failure injection: retried, training completes
    calls = {"n": 0}
    def injector(step, attempt):
        if step == 6 and attempt == 0:
            calls["n"] += 1
            raise RuntimeError("injected preemption")
    lc3 = LoopConfig(total_steps=7, ckpt_every=10, ckpt_dir=str(tmp_path / "ck"),
                     log_every=100, retry_backoff_s=0.01)
    st3 = train(model, shape, None, loop_cfg=lc3, fail_injector=injector)
    assert calls["n"] == 1 and st3.step == 7


# ---- compression ------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantize_error_bound(seed):
    x = jax.random.normal(jax.random.key(seed), (256,)) * 10
    q, s = compression.quantize(x)
    err = jnp.abs(compression.dequantize(q, s) - x).max()
    assert err <= s * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    x = jnp.full((64,), 0.3)
    res = {"g": jnp.zeros((64,))}
    total_plain = jnp.zeros((64,))
    total_ef = jnp.zeros((64,))
    for _ in range(50):
        total_plain += compression.compress_decompress({"g": x})["g"]
        g, res = compression.compress_decompress({"g": x}, res)
        total_ef += g["g"]
    target = 50 * 0.3
    assert jnp.abs(total_ef - target).max() <= jnp.abs(total_plain - target).max() + 1e-5


# ---- sharding rules ---------------------------------------------------------

class _FakeMesh:
    def __init__(self, axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_logical_fallback_on_indivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible: sharded
    spec = logical_to_spec(("vocab", "embed"), (128256, 4096), mesh)
    assert spec == jax.sharding.PartitionSpec("model", "data")
    # 40 heads % 16 != 0 -> replicated on that dim
    spec = logical_to_spec(("embed", "heads"), (5120, 5120), mesh)
    assert spec[0] == "data"
    spec2 = logical_to_spec(("embed", "heads"), (5120, 40 * 128), mesh)
    assert spec2 == jax.sharding.PartitionSpec("data", "model")
    # odd vocab -> no vocab sharding but embed still fsdp
    spec3 = logical_to_spec(("vocab", "embed"), (49155, 1536), mesh)
    assert spec3[0] is None and spec3[1] == "data"


def test_logical_no_axis_reuse():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("heads", "ff"), (512, 1024), mesh)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_multipod_roles():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(("layers", "embed", "ff"), (32, 4096, 14336), mesh)
    assert spec == jax.sharding.PartitionSpec(None, ("pod", "data"), "model")
    # batch of 1 -> fully replicated
    spec = logical_to_spec(("batch", None), (1, 128), mesh)
    assert spec == jax.sharding.PartitionSpec()
