"""Shared pytest configuration for the suite."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: spawns a subprocess / long wall-clock (kept in tier-1, but "
        "deselectable with -m 'not slow')")
