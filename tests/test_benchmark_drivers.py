"""Smoke coverage for the benchmark drivers that had no test tier:
``benchmarks/roofline_report.py`` (pure table rendering from results JSONL)
and ``benchmarks/futurework_study.py`` (the beyond-paper knob study, now
batched through ``suite.speedup_batch``)."""
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import futurework_study, roofline_report  # noqa: E402


# ----------------------------------------------------- futurework_study

def test_futurework_study_quick_table():
    table = futurework_study.study(
        apps=["blackscholes", "canneal"],
        variants={"baseline(in-order,ring,1rp,1mp)": {},
                  "all_upgrades": {"ooo_issue": True,
                                   "interconnect": "crossbar",
                                   "vrf_read_ports": 3, "mem_ports": 2}})
    base = table["baseline(in-order,ring,1rp,1mp)"]
    assert all(v == 1.0 for v in base.values())
    for row in table.values():
        for v in row.values():
            assert np.isfinite(v) and v > 0
    # upgrading every §3 knob never slows an app down at the reference point
    assert all(v >= 0.999 for v in table["all_upgrades"].values())


def test_futurework_study_baseline_found_by_name_not_order():
    a = futurework_study.study(
        apps=["canneal"],
        variants={"baseline(in-order,ring,1rp,1mp)": {},
                  "all_upgrades": {"ooo_issue": True, "vrf_read_ports": 3}})
    b = futurework_study.study(
        apps=["canneal"],
        variants={"all_upgrades": {"ooo_issue": True, "vrf_read_ports": 3},
                  "baseline(in-order,ring,1rp,1mp)": {}})
    assert a["all_upgrades"]["canneal"] == b["all_upgrades"]["canneal"]
    assert b["baseline(in-order,ring,1rp,1mp)"]["canneal"] == 1.0


def test_futurework_study_main_quick(capsys):
    assert futurework_study.main(["--quick"]) == 0
    out = capsys.readouterr().out
    assert "variant" in out and "ooo_issue" in out


# ----------------------------------------------------- roofline_report

_ROW = {
    "arch": "dense", "shape": "8b", "mesh": "16x16",
    "compile_s": 12.5,
    "per_device": {"hbm_used_bytes": 9 * 2 ** 30, "fits_16GB": True,
                   "flops": 1.2e12, "ici_bytes": 3.4e9},
    "roofline": {"t_compute_s": 0.51, "t_memory_s": 0.21,
                 "t_collective_s": 0.11, "bound": "compute",
                 "useful_ratio": 0.92, "roofline_fraction": 0.8123},
}


def test_roofline_report_renders_tables(tmp_path, capsys):
    other = dict(_ROW, mesh="8x8")         # filtered from the roofline table
    tagged = dict(_ROW, tag="hillclimb")   # filtered from the dry-run table
    with open(tmp_path / "dryrun.jsonl", "w") as f:
        for r in (_ROW, other, tagged):
            f.write(json.dumps(r) + "\n")
    assert roofline_report.main(["--results", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "| dense | 8b | 16x16 | 12.5 | 9.00 | yes |" in out
    assert "| dense | 8b | 0.51 | 0.21 | 0.11 | compute | 0.92 | 0.8123 |" \
        in out
    # the dry-run table lists both meshes, the roofline table only 16x16
    assert out.count("| dense | 8b |") == 3


def test_roofline_report_handles_missing_results(tmp_path, capsys):
    assert roofline_report.main(["--results", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "Dry-run table" in out and "Roofline" in out
