"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (full configs are exercised only by the dry-run).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build


def _batch(cfg, B=2, S=16):
    k = jax.random.key(1)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.num_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k, (B, cfg.num_patches, cfg.d_model), jnp.float32)
        batch["tokens"] = batch["tokens"][:, :S - cfg.num_patches]
        batch["labels"] = batch["labels"][:, :S - cfg.num_patches]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch, max_seq=32)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.int32(16))
    assert jnp.isfinite(logits2).all(), arch
    # caches keep their structure/shapes
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_logical_matches_structs(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    structs = jax.tree.leaves(model.param_structs())
    logical = jax.tree.leaves(
        model.param_logical(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(structs) == len(logical)
    for s, lg in zip(structs, logical):
        assert len(s.shape) == len(lg), (s.shape, lg)


def test_decode_matches_forward_next_token():
    """Teacher-forced forward and prefill+decode agree on next-token argmax."""
    cfg = get_config("llama3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks}, max_seq=16)
    from repro.models import transformer as T
    h = T.forward(params, toks, cfg)
    from repro.models import layers as L
    full_logits = L.unembed_fwd(params["embed"], h)
    assert jnp.argmax(logits[0, -1]) == jnp.argmax(full_logits[0, -1])
