"""Mechanistic cycle-attribution profiler contracts (ISSUE 10).

Three load-bearing guarantees:

  * the profiling scan is the default scan: every shared metric is
    bitwise-identical with and without ``collect_stats`` on random traces
    and configs (the attribution reads the step's intermediates, it never
    rewrites them),
  * the event-sum identity: the attributed cycles over ``STALL_KINDS``
    reconstruct the total runtime to float32 association tolerance on every
    app at a config sample (nothing double-counted, nothing dropped),
  * cost containment: turning profiling on adds at most one jit executable
    per trace shape (the single ``_profile_jit`` key).

Plus schema/scorecard/timeline/histogram/utilization sanity for the
telemetry layer itself.
"""
import json

import numpy as np
import pytest

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from repro.testing.hypothesis_shim import given, settings, strategies as st

from repro.core import engine as eng
from repro.core import suite, telemetry, tracegen
from test_properties import random_config, random_trace

seeds = st.integers(min_value=0, max_value=10 ** 9)

CFG_REF = eng.VectorEngineConfig(mvl=64, lanes=4)
CFG_CORNER = eng.VectorEngineConfig(mvl=256, lanes=8, ooo_issue=True,
                                    interconnect="crossbar")


# --------------------------------------------------------------------------
# contract 1: the default path is untouched
# --------------------------------------------------------------------------
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_collect_stats_timing_bitwise(seed):
    """simulate(collect_stats=True) returns the exact default metrics —
    bitwise — on random traces and random configs."""
    tr, cfg = random_trace(seed), random_config(seed)
    base = eng.simulate(tr, cfg)
    prof = eng.simulate(tr, cfg, collect_stats=True)
    for k, v in base.items():
        assert prof[k] == v, (k, v, prof[k])


# --------------------------------------------------------------------------
# contract 2: event-sum identity across the whole suite
# --------------------------------------------------------------------------
@pytest.mark.parametrize("app", sorted(tracegen.APPS))
def test_event_sum_identity(app):
    """sum(stalls) == time to float32 tolerance, every app, both the
    reference config and the ooo/crossbar corner."""
    for cfg in (CFG_REF, CFG_CORNER):
        body = tracegen.body_for(app, suite.effective_mvl(app, cfg), cfg)
        prof = eng.simulate(body.tile(8), cfg, collect_stats=True)
        total = sum(prof["stalls"].values())
        assert abs(total - prof["time"]) <= 1e-4 * prof["time"], (
            app, cfg.label(), total, prof["time"])
        assert all(v >= 0.0 for v in prof["stalls"].values())


def test_records_timeline_sane():
    body = tracegen.body_for("blackscholes", 64, CFG_REF)
    prof = eng.simulate(body.tile(4), CFG_REF, collect_stats=True)
    rec = prof["records"]
    n = len(body.tile(4))
    assert all(rec[k].shape == (n,) for k in ("start", "issue", "complete"))
    assert np.all(rec["issue"] <= rec["complete"] + 1e-6)
    assert np.all(rec["complete"] <= prof["time"] + 1e-6)
    assert rec["cause"].min() >= 0 and rec["cause"].max() < eng.N_STALL


# --------------------------------------------------------------------------
# contract 3: one extra executable per trace shape
# --------------------------------------------------------------------------
def test_profiling_adds_at_most_one_executable():
    if eng.jit_cache_size() == -1:
        pytest.skip("jit cache introspection unavailable")
    tr = random_trace(12345)
    cfg_a, cfg_b = random_config(1), random_config(2)
    eng.simulate(tr, cfg_a)                     # warm the default key
    n0 = eng.jit_cache_size()
    eng.simulate(tr, cfg_a, collect_stats=True)
    eng.simulate(tr, cfg_b, collect_stats=True)  # flags are traced args
    assert eng.jit_cache_size() - n0 <= 1


# --------------------------------------------------------------------------
# telemetry layer: schema, rollup, scorecard, timeline, histogram
# --------------------------------------------------------------------------
def test_schema_envelope():
    row = telemetry.snapshot_row("x.y", a=1)
    assert row["schema"] == telemetry.SCHEMA
    assert row["kind"] == "x.y" and row["a"] == 1


def test_module_rollup_total():
    """Every stall kind maps to exactly one module and the module fractions
    sum to ~1 (they partition the event-sum identity)."""
    assert set(telemetry._KIND_TO_MODULE) == set(eng.STALL_KINDS)
    for app in ("blackscholes", "canneal"):
        r = telemetry.profile_app(app, CFG_REF, tiles=8)
        assert r["kind"] == "engine.profile"
        assert abs(sum(r["modules"].values()) - 1.0) < 1e-3
        assert r["top"] in telemetry.MODULES
        assert r["identity_rel_err"] < 1e-4


def test_scorecard_roundtrip():
    rep = telemetry.scorecard(apps=["blackscholes", "pathfinder"],
                              cfgs=[CFG_REF], tiles=4)
    doc = json.loads(rep.to_json())
    assert doc["schema"] == telemetry.SCHEMA and len(doc["rows"]) == 2
    assert "blackscholes" in rep.table()
    assert set(rep.by_app()) == {"blackscholes", "pathfinder"}


def test_chrome_trace_valid(tmp_path):
    body = tracegen.body_for("jacobi-2d", 64, CFG_REF)
    path = tmp_path / "timeline.json"
    doc = telemetry.write_chrome_trace(str(path), body.tile(2), CFG_REF,
                                       label="jacobi-2d")
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" for e in evs)
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans, "no complete-event spans"
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["tid"] in (0, 1, 2)
    assert any(e["name"].startswith("stall:") for e in spans)


def test_latency_histogram():
    h = telemetry.LatencyHistogram()
    for v in (2e-6, 5e-5, 1e-3, 1e-3, 2.0):
        h.add(v)
    assert h.count == 5
    p50 = h.percentile(0.5)
    assert 5e-5 <= p50 <= 2e-3
    assert h.percentile(1.0) >= h.percentile(0.5) >= h.percentile(0.0)
    d = h.to_dict()
    assert d["kind"] == "latency.hist" and d["count"] == 5
    # per-window deltas: since() only sees what was added after snapshot()
    snap = h.snapshot()
    h.add(1e-2)
    delta = h.since(snap)
    assert delta.count == 1
    assert abs(delta.percentile(0.5) - 1e-2) / 1e-2 < 0.2
    # out-of-range values land in the clamp bins, not off the end
    h.add(1e-9), h.add(1e6)
    assert h.count == 8


def test_sweep_utilization_columns():
    """suite.sweep(utilization=True) rides the same fused scan: speedups
    bitwise-equal to the default sweep, utilizations physically sane."""
    mvls, lanes = (8, 64), (1, 4)
    plain = suite.sweep("blackscholes", mvls=mvls, lanes=lanes)
    rich = suite.sweep("blackscholes", mvls=mvls, lanes=lanes,
                       utilization=True)
    for cell, row in rich.items():
        assert row["speedup"] == plain[cell]
        assert 0.0 <= row["lane_util"] <= 1.0 + 1e-6
        assert 0.0 <= row["vmu_util"] <= 1.0 + 1e-6
    # 1 lane saturates on a compute-heavy body; 4 lanes has more headroom
    assert rich[(64, 1)]["lane_util"] >= rich[(64, 4)]["lane_util"] - 1e-6


def test_steady_state_with_util():
    body = tracegen.body_for("blackscholes", 64, CFG_REF)
    plain = eng.steady_state_time_batch([body], [CFG_REF])
    rich = eng.steady_state_time_batch([body], [CFG_REF], with_util=True)
    assert rich[0]["steady_ns"] == plain[0]
    assert 0.0 <= rich[0]["lane_util"] <= 1.0 + 1e-6
    assert 0.0 <= rich[0]["vmu_util"] <= 1.0 + 1e-6


def test_dep_scalar_attribution_matches_table2():
    """Coupling cycles (dep_scalar) surface for exactly the scalar-
    communication apps of the paper's Table 2."""
    scalar_comm = {"canneal", "particlefilter", "streamcluster",
                   "flash_attention", "decode_attention"}
    for app in sorted(tracegen.APPS):
        body = tracegen.body_for(app, suite.effective_mvl(app, CFG_REF),
                                 CFG_REF)
        prof = eng.simulate(body.tile(8), CFG_REF, collect_stats=True)
        has = prof["stalls"]["dep_scalar"] > 0
        assert has == (app in scalar_comm), (app, prof["stalls"]["dep_scalar"])
