"""Surrogate-guided search tests: survivor selection, index algebra, the
exact-resim guarantee, determinism, and frontier recall on a space the test
can afford to exhaust.
"""
import numpy as np
import pytest

from repro.core import dse, search, surrogate
from repro.configs import vector_engine as vcfg

APPS = ("blackscholes", "canneal")


@pytest.fixture(scope="module")
def trained():
    """One smoke-space explore + fit shared by the end-to-end tests; the
    cache retains every exact cell so searches re-hit it."""
    cache = dse.ResultCache()
    truth = dse.explore(vcfg.SPACE_SMOKE, APPS, cache=cache)
    rows = cache.export_training_rows(APPS, vcfg.SPACE_SMOKE)
    model = surrogate.fit(rows, steps=400, seed=0)
    return cache, truth, model


# ------------------------------------------------------- survivor selection

def test_survivors_keep_near_frontier_band():
    idx = np.array([7, 3, 9, 5])
    pred = np.array([10.0, 11.0, 30.0, 5.0])
    area = np.array([1.0, 1.0, 2.0, 3.0])
    # band: the two area-1 points (10 within 15% of 10) and the pred-5 point
    assert search._survivors(idx, pred, area, eps=0.15, cap=10).tolist() \
        == [3, 5, 7]


def test_survivors_stratify_across_area_not_collapse():
    """With a tight cap the kept survivors must span the area range, not
    cluster at the lowest flat indices (the coverage property recall depends
    on)."""
    n = 10_000
    idx = np.arange(n)
    area = np.linspace(1.0, 100.0, n)
    pred = 1000.0 / (area + 1.0)             # smooth predicted frontier
    kept = search._survivors(idx, pred, area, eps=0.5, cap=64)
    assert len(kept) <= 64
    kept_areas = area[kept]
    assert kept_areas.min() < 10.0 and kept_areas.max() > 90.0
    # deterministic
    again = search._survivors(idx, pred, area, eps=0.5, cap=64)
    assert np.array_equal(kept, again)


def test_survivors_depth_keeps_backups_per_stratum():
    n = 1000
    idx = np.arange(n)
    area = np.linspace(1.0, 10.0, n)
    pred = np.full(n, 100.0)                 # everything ties the frontier
    got = search._survivors(idx, pred, area, eps=0.1, cap=30, depth=3)
    assert len(got) == 30                    # 10 strata x 3 backups


# ------------------------------------------------------------ index algebra

def test_decode_encode_roundtrip_matches_config_at():
    sp = vcfg.SPACE_10K
    radices = [len(c) for _, c in sp.axes]
    idx = np.array([0, 1, 17, 4095, sp.size() - 1])
    digits = search._decode(idx, radices)
    assert np.array_equal(search._encode(digits, radices), idx)
    # digits agree with the configs config_at() builds
    names = [n for n, _ in sp.axes]
    choices = [c for _, c in sp.axes]
    for k, i in enumerate(idx):
        cfg = sp.config_at(int(i))
        for a, name in enumerate(names):
            assert getattr(cfg, name) == choices[a][digits[k, a]], (i, name)


def test_neighbors_are_exact_hamming_one():
    radices = [3, 2, 2]
    nbrs = search._neighbors(np.array([0]), radices)
    digits0 = search._decode(np.array([0]), radices)[0]
    assert len(nbrs) == (3 - 1) + (2 - 1) + (2 - 1)
    for n in nbrs:
        d = search._decode(np.array([n]), radices)[0]
        assert int((d != digits0).sum()) == 1
    assert len(search._neighbors(np.empty(0, np.int64), radices)) == 0


# ------------------------------------------------------------------ recall

def test_frontier_recall_bounds():
    from types import SimpleNamespace as R
    truth = [R(runtime_ns=10.0, area_kb=5.0), R(runtime_ns=20.0, area_kb=1.0)]
    assert search.frontier_recall([], truth) == 0.0
    assert search.frontier_recall(truth, truth) == 1.0
    assert search.frontier_recall(truth, []) == 1.0
    # strictly-better points weakly dominate
    assert search.frontier_recall(
        [R(runtime_ns=5.0, area_kb=0.5)], truth) == 1.0


# ------------------------------------------------------------- end to end

def test_search_frontier_is_exact_and_bitwise_repeatable(trained):
    cache, truth, model = trained
    res1 = search.search(vcfg.SPACE_SMOKE, APPS, model, cache=cache,
                         seed=0, max_resim_per_app=16, refine_rounds=1)
    res2 = search.search(vcfg.SPACE_SMOKE, APPS, model, cache=cache,
                         seed=0, max_resim_per_app=16, refine_rounds=1)
    assert search.frontier_fingerprint(res1) \
        == search.frontier_fingerprint(res2)
    # every frontier point is backed by an exact cached engine result whose
    # runtime re-derives bitwise — the never-report-a-prediction guarantee
    assert search._verify_exact(res1, cache) == sum(
        len(f) for f in res1.frontiers.values())


def test_search_recovers_exhaustive_frontier_when_it_can_refine(trained):
    """Searching the very space the exact explore exhausted: the surrogate
    plus one refinement round must recover the exhaustive Pareto frontier
    (recall 1.0) while nominating far fewer than 64 configs up front."""
    cache, truth, model = trained
    res = search.search(vcfg.SPACE_SMOKE, APPS, model, cache=cache,
                        seed=0, max_resim_per_app=16, refine_rounds=2)
    tf = truth.frontiers()
    for app in APPS:
        assert search.frontier_recall(res.frontiers[app], tf[app]) == 1.0, app
        assert res.stats["resim"][app]["resim"] <= vcfg.SPACE_SMOKE.size()
    assert res.stats["mode"] == "exhaustive-score"


def test_search_evolutionary_path_is_deterministic(trained):
    cache, _, model = trained
    kw = dict(cache=cache, seed=3, max_resim_per_app=12, refine_rounds=1,
              exhaustive_limit=0, rounds=2, pop=512)
    r1 = search.search(vcfg.SPACE_SMOKE, APPS, model, **kw)
    r2 = search.search(vcfg.SPACE_SMOKE, APPS, model, **kw)
    assert r1.stats["mode"] == "evolutionary"
    assert search.frontier_fingerprint(r1) == search.frontier_fingerprint(r2)
    search._verify_exact(r1, cache)


def test_search_records_only_contain_exact_dse_records(trained):
    cache, _, model = trained
    res = search.search(vcfg.SPACE_SMOKE, APPS, model, cache=cache,
                        seed=0, max_resim_per_app=8, refine_rounds=0)
    for app in APPS:
        for r in res.records[app]:
            assert isinstance(r, dse.DseRecord)
            assert r.area_kb == dse.area_proxy_kb(r.cfg)
        # the frontier is the Pareto set of exactly those records
        want = dse.pareto_frontier(res.records[app])
        assert [(w.label, w.runtime_ns) for w in want] == \
            [(f.label, f.runtime_ns) for f in res.frontiers[app]]
