"""Jaxpr→vector-IR frontend: lowering unit tests + the cross-validation
contract (derived bodies vs hand-coded tracegen bodies) + the three
frontend-only ML workloads."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import frontend as fe
from repro.core import isa, tracegen


def _kinds(tr):
    return {isa.KIND_NAMES[k]: int(n)
            for k, n in enumerate(isa.kind_histogram(tr)) if n}


# ---------------------------------------------------------------- lowering

def test_elementwise_fu_classes():
    def fn(a, b):
        x = a + b                  # simple
        y = x * b                  # mul
        z = y / a                  # div
        return jnp.exp(z)          # trans

    tr = fe.lower_trace([fe.KernelBody(fn, 64,
                                       ins=(fe.Stream("a", 8.0),
                                            fe.Stream("b", 8.0)),
                                       outs=(fe.Stream("o", 8.0),))])
    assert _kinds(tr) == {"load": 2, "arith": 4, "store": 1}
    fus = tr.fu[tr.kind == isa.VARITH]
    assert list(fus) == [isa.FU_SIMPLE, isa.FU_MUL, isa.FU_DIV, isa.FU_TRANS]
    assert all(tr.vl[tr.kind != isa.SCALAR_BLOCK] == 64)


def test_roll_lowers_to_slide_and_reduce_to_vreduce():
    def fn(a):
        s = jnp.roll(a, 1)
        return jnp.sum(s + a)

    tr = fe.lower_trace([fe.KernelBody(fn, 32, ins=(fe.Stream("a", 8.0),))])
    assert _kinds(tr) == {"load": 1, "slide": 1, "arith": 1, "reduce": 1}
    assert tr.vl[tr.kind == isa.VREDUCE][0] == 32


def test_bool_reduction_is_mask_to_scalar():
    def fn(a):
        return jnp.any(a > 0.0), jnp.all(a > 1.0)

    tr = fe.lower_trace([fe.KernelBody(fn, 16, ins=(fe.Stream("a", 8.0),))])
    k = _kinds(tr)
    assert k["mask2s"] == 2 and k["arith"] == 2  # two compares, two vfirst/vpopc


def test_cumsum_expands_to_slide_add_ladder():
    tr = fe.lower_trace([fe.KernelBody(lambda a: jnp.cumsum(a), 64,
                                       ins=(fe.Stream("a", 8.0),))])
    k = _kinds(tr)
    assert k["slide"] == 6 and k["arith"] == 6   # ceil(log2(64)) rounds


def test_gather_becomes_indexed_load_with_stream_footprint():
    def fn(x, i):
        idx = jnp.clip(i, 0.0, 7.0).astype(jnp.int32)
        return x[idx]

    tr = fe.lower_trace([fe.KernelBody(fn, 8,
                                       ins=(fe.Stream("table", 3072.0),
                                            fe.Stream("idx", 8.0),))])
    gathers = (tr.kind == isa.VLOAD) & (tr.mem_pattern == isa.MEM_INDEXED)
    assert gathers.sum() == 1
    assert tr.footprint_kb[gathers][0] == np.float32(3072.0)


def test_scalar_eqns_coalesce_and_dep_on_reductions():
    def fn(a):
        m = jnp.sum(a)             # VREDUCE, result handed to scalar core
        c = m * 2.0 + 1.0          # two rank-0 eqns -> one dep SCALAR_BLOCK
        return a + c               # broadcast back into a vector op

    tr = fe.lower_trace([fe.KernelBody(fn, 16, ins=(fe.Stream("a", 8.0),))])
    blocks = tr.kind == isa.SCALAR_BLOCK
    assert blocks.sum() == 1
    assert tr.scalar_count[blocks][0] == 2
    assert tr.dep_scalar[blocks][0]


def test_stream_patterns_and_declared_scalar_work():
    segs = [fe.ScalarWork(12.6, dep_scalar=True),
            fe.KernelBody(lambda a, b: a + b, 8,
                          ins=(fe.Stream("u", 64.0),
                               fe.Stream("s", 64.0, pattern=isa.MEM_STRIDED)),
                          outs=(fe.Stream("o", 64.0),))]
    tr = fe.lower_trace(segs)
    assert tr.scalar_count[0] == 13 and tr.dep_scalar[0]
    loads = tr.mem_pattern[tr.kind == isa.VLOAD]
    assert sorted(loads) == [isa.MEM_UNIT, isa.MEM_STRIDED]


def test_named_values_cross_segments():
    segs = [fe.KernelBody(lambda a: a * a, 8,
                          ins=(fe.Stream("a", 8.0),), outs=("sq",)),
            fe.KernelBody(lambda sq: jnp.sum(sq), 8, ins=("sq",))]
    tr = fe.lower_trace(segs)
    assert _kinds(tr) == {"load": 1, "arith": 1, "reduce": 1}
    # the reduce reads the register the first segment's result lives in
    arith = np.flatnonzero(tr.kind == isa.VARITH)[0]
    red = np.flatnonzero(tr.kind == isa.VREDUCE)[0]
    assert tr.src1[red] == tr.dst[arith]


def test_register_pressure_errors_and_lazy_loads():
    n = fe.N_LOGICAL_REGS + 4
    streams = tuple(fe.Stream(f"s{i}", 8.0) for i in range(n))

    def fold(*xs):
        acc = xs[0]
        for x in xs[1:]:
            acc = acc + x
        return acc

    def hold(*xs):                       # all streams live until the end
        acc = xs[0]
        for x in xs[1:]:
            acc = acc + x
        return tuple(xs)

    outs = tuple(fe.Stream(f"o{i}", 8.0) for i in range(n))
    with pytest.raises(fe.FrontendError, match="register pressure"):
        fe.lower_trace([fe.KernelBody(hold, 8, ins=streams, outs=outs)])
    low = fe.lower([fe.KernelBody(fold, 8, ins=streams, lazy_loads=True)])
    assert low.max_live <= 4
    assert _kinds(low.trace) == {"load": n, "arith": n - 1}


def test_unknown_primitive_is_loud():
    with pytest.raises(fe.FrontendError, match="no vector-IR mapping"):
        fe.lower_trace([fe.KernelBody(
            lambda a: jnp.dot(a.reshape(4, 2), a.reshape(2, 4)), 8,
            ins=(fe.Stream("a", 8.0),))])


def test_unused_blocks_are_still_fetched():
    tr = fe.lower_trace([fe.KernelBody(lambda a, b: a * 2.0, 8,
                                       ins=(fe.Stream("a", 8.0),
                                            fe.Stream("b", 8.0)),
                                       lazy_loads=True)])
    assert _kinds(tr)["load"] == 2       # block-spec semantics: b fetched too


# --------------------------------------- differential: random jax kernels vs
# a reference interpreter over the jaxpr


# The random-kernel op pool: a subset of the supported primitive set whose
# jaxpr spelling is stable (each entry is (callable, jaxpr primitive name)).
_OP_POOL = (
    (lambda a, b: a + b, "add"),
    (lambda a, b: a - b, "sub"),
    (lambda a, b: jnp.maximum(a, b), "max"),
    (lambda a, b: jnp.minimum(a, b), "min"),
    (lambda a, b: a * b, "mul"),
    (lambda a, b: a / b, "div"),
    (lambda a, b: jnp.sqrt(a) + b * 0, "sqrt"),
    (lambda a, b: jnp.exp(a) + b * 0, "exp"),
    (lambda a, b: jnp.tanh(a) + b * 0, "tanh"),
)
_TERMINALS = ("none", "sum", "roll", "cumsum", "any")


def _random_kernel(seed, n_ops=6, n_ins=2):
    """A random elementwise kernel from the supported primitive set: the op
    sequence and operand wiring are drawn *outside* the traced function, so
    the same structure is replayed identically at trace time."""
    rng = np.random.RandomState(seed)
    plan = [(int(rng.randint(len(_OP_POOL))),
             int(rng.randint(n_ins + i)), int(rng.randint(n_ins + i)))
            for i in range(n_ops)]
    terminal = _TERMINALS[rng.randint(len(_TERMINALS))]

    def fn(*ins):
        vals = list(ins)
        for op_i, s1, s2 in plan:
            vals.append(_OP_POOL[op_i][0](vals[s1], vals[s2]))
        out = vals[-1]
        if terminal == "sum":
            return jnp.sum(out)
        if terminal == "roll":
            return jnp.roll(out, 1) + out
        if terminal == "cumsum":
            return jnp.cumsum(out)
        if terminal == "any":
            return jnp.any(out > 0.0)
        return out

    return fn, terminal


def _reference_counts(jaxpr, vl):
    """Independent reference interpreter over a jaxpr: predicts the lowered
    trace's kind/FU/element totals by walking equations directly — no
    walker state, no register allocation, no scalar coalescing — so a
    bookkeeping bug in the lowering pipeline cannot cancel itself out."""
    fu_hist = np.zeros(4, int)
    counts = {"slide": 0, "reduce": 0, "mask": 0, "elems": 0}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in fe.CALL_PRIMS:
            p = eqn.params
            inner = next(p[k] for k in ("jaxpr", "call_jaxpr", "fun_jaxpr")
                         if k in p)
            sub_fu, sub_counts = _reference_counts(
                inner.jaxpr if hasattr(inner, "jaxpr") else inner, vl)
            fu_hist += sub_fu
            for k in counts:
                counts[k] += sub_counts[k]
        elif name in fe.SKIP_PRIMS:
            continue
        elif name in fe.CUMULATIVE_FU:
            rounds = max(1, int(np.ceil(np.log2(max(vl, 2)))))
            counts["slide"] += rounds
            fu_hist[fe.CUMULATIVE_FU[name]] += rounds
            counts["elems"] += 2 * rounds * vl
        elif name in fe.REDUCE_FU:
            counts["reduce"] += 1
            counts["elems"] += vl
        elif name in fe.MASK_PRIMS:
            counts["mask"] += 1
            counts["elems"] += vl
        elif name in fe.SLIDE_PRIMS:
            counts["slide"] += 1
            counts["elems"] += vl
        elif name in fe.FU_OF_PRIM:
            if eqn.outvars[0].aval.shape:
                fu_hist[fe.FU_OF_PRIM[name]] += 1
                counts["elems"] += vl
        else:  # a pool op lowering to an unexpected primitive
            raise AssertionError(f"unmapped primitive {name!r}")
    return fu_hist, counts


@pytest.mark.parametrize("seed", range(12))
def test_differential_random_kernels_match_reference(seed):
    """Random small kernels from the supported primitive set: the full
    lowering pipeline (walker -> register allocator -> TraceBuilder) must
    produce exactly the FU/kind/element/pattern mix the reference
    interpreter reads off the jaxpr."""
    import jax
    vl = int((16, 64)[seed % 2])
    fn, terminal = _random_kernel(seed)
    ins = tuple(fe.Stream(f"s{i}", 64.0) for i in range(2))
    tr = fe.lower_trace([fe.KernelBody(fn, vl, ins=ins)])

    avals = [jax.ShapeDtypeStruct((vl,), jnp.float32) for _ in ins]
    ref_fu, ref = _reference_counts(jax.make_jaxpr(fn)(*avals).jaxpr, vl)

    got_fu = np.bincount(tr.fu[tr.kind == isa.VARITH], minlength=4)
    assert list(got_fu) == list(ref_fu), (terminal, got_fu, ref_fu)
    assert int((tr.kind == isa.VSLIDE).sum()) == ref["slide"]
    assert int((tr.kind == isa.VREDUCE).sum()) == ref["reduce"]
    assert int((tr.kind == isa.VMASK_SCALAR).sum()) == ref["mask"]
    # loads come only from the declared streams; element work matches
    loads = tr.kind == isa.VLOAD
    assert int(loads.sum()) == len(ins)
    assert all(tr.mem_pattern[loads] == isa.MEM_UNIT)
    vec = (tr.kind != isa.SCALAR_BLOCK) & ~loads & (tr.kind != isa.VSTORE)
    assert int(tr.vl[vec].sum()) == ref["elems"], terminal


# ------------------------------------------------- the cross-validation gate

def test_cross_validation_all_rivec_apps():
    """ISSUE acceptance: derived traces match all 7 hand-coded bodies —
    instruction-kind mix exact, steady-state time within 5%."""
    reports = fe.cross_validate_all()
    assert {r.app for r in reports} == set(tracegen.RIVEC_APPS)
    bad = [(r.app, r.time_rel_err) for r in reports if not r.ok]
    assert not bad, bad
    for r in reports:
        assert r.kinds_ok and r.fu_ok and r.pattern_ok
        assert r.elems_ok and r.scalar_ok and r.pressure_ok


# ------------------------------------------------- frontend-only workloads

ML_APPS = ("flash_attention", "decode_attention", "ssd_scan")


def test_ml_workloads_registered_and_lowerable():
    for app in ML_APPS:
        a = tracegen.APPS[app]
        assert a.kernel is not None
        tr = tracegen.body_for(app, 64, eng.VectorEngineConfig(mvl=64, lanes=4))
        kinds = _kinds(tr)
        assert kinds.get("load", 0) > 0 and kinds.get("arith", 0) > 0
        counts = a.counts(64)
        assert counts.vector_ops > 0 and counts.total_vector > 0
        assert 0.99 < sum(a.mix.values()) < 1.01


def test_ml_workload_profiles():
    """The three workloads stress distinct modules: ssd the slide ladder,
    the attention kernels reductions + the scalar round trip."""
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    fa = tracegen.body_for("flash_attention", 64, cfg)
    da = tracegen.body_for("decode_attention", 64, cfg)
    ssd = tracegen.body_for("ssd_scan", 64, cfg)
    assert (ssd.kind == isa.VSLIDE).sum() >= 6          # cumsum ladder
    for tr in (fa, da):
        assert (tr.kind == isa.VREDUCE).sum() > 32      # per-dim dots
        assert tr.dep_scalar.sum() >= 1                 # m/l scalar update
    assert ((da.kind == isa.VLOAD)
            & (da.mem_pattern == isa.MEM_STRIDED)).sum() > 0


def test_ml_workloads_in_full_sweep():
    from repro.core import suite
    table = suite.sweep_all(ML_APPS, mvls=(8, 256), lanes=(1, 8))
    for app in ML_APPS:
        for v in table[app].values():
            assert np.isfinite(v) and v > 0
    # decode is DRAM-bandwidth bound: lanes buy almost nothing
    d = table["decode_attention"]
    assert d[(256, 8)] / d[(256, 1)] < 1.3
    # ssd scales with lanes at large MVL (compute bound)
    s = table["ssd_scan"]
    assert s[(256, 8)] / s[(256, 1)] > 2.0
