"""Surrogate cost-model tests: features, training, batched scoring, scorecard.

Kept cheap: one 64-point SPACE_SMOKE explore labels the training rows
(module-scoped), fits run a few hundred full-batch steps (~1 s).
"""
import numpy as np
import pytest

from repro.core import dse, surrogate, tracegen
from repro.core import engine as eng
from repro.configs import vector_engine as vcfg

APPS = ("blackscholes", "canneal")


@pytest.fixture(scope="module")
def labeled():
    cache = dse.ResultCache()
    dse.explore(vcfg.SPACE_SMOKE, APPS, cache=cache)
    rows = cache.export_training_rows(APPS, vcfg.SPACE_SMOKE)
    assert len(rows) == 128
    return cache, rows


@pytest.fixture(scope="module")
def model(labeled):
    _, rows = labeled
    return surrogate.fit(rows, steps=400, seed=0)


# ----------------------------------------------------------------- features

def test_config_features_cover_every_live_knob():
    import dataclasses
    assert set(surrogate.CONFIG_FEATURES) == {
        f.name for f in dataclasses.fields(eng.VectorEngineConfig)}
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4, ooo_issue=True,
                                 interconnect="crossbar")
    feats = surrogate.config_features(cfg)
    assert feats.shape == (len(surrogate.CONFIG_FEATURES),)
    f = dict(zip(surrogate.CONFIG_FEATURES, feats))
    assert f["mvl"] == 64.0 and f["lanes"] == 4.0
    assert f["ooo_issue"] == 1.0            # bool -> 0/1
    assert f["interconnect"] == 0.0         # crossbar=0, ring=1
    assert surrogate.config_features(
        eng.VectorEngineConfig())[list(surrogate.CONFIG_FEATURES)
                                  .index("interconnect")] == 1.0


def test_trace_features_key_on_app_and_mvl_only():
    a = surrogate.trace_features("blackscholes", 64)
    b = surrogate.trace_features("blackscholes", 64)
    assert a is b                            # memoized
    assert a.shape == (len(surrogate.TRACE_FEATURES),)
    assert np.isfinite(a).all()
    # a different MVL and a different app both change the features
    assert not np.array_equal(a, surrogate.trace_features("blackscholes", 8))
    assert not np.array_equal(a, surrogate.trace_features("canneal", 64))


def test_trace_features_match_characterize_closed_forms():
    from repro.core import characterize
    feats = dict(zip(surrogate.TRACE_FEATURES,
                     surrogate.trace_features("swaptions", 64)))
    c = characterize.characterize("swaptions", 64)
    assert feats["pct_vectorization"] == pytest.approx(c.pct_vectorization)
    assert feats["avg_vl_counts"] == pytest.approx(c.avg_vl)
    # canneal caps at max_vl=22: the effective-MVL feature reflects the clamp
    f2 = dict(zip(surrogate.TRACE_FEATURES,
                  surrogate.trace_features("canneal", 256)))
    assert f2["eff_mvl"] == 22.0


def test_row_features_concatenate_config_and_trace():
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    row = surrogate.row_features("blackscholes", cfg)
    assert row.shape == (surrogate.N_FEATURES,)
    n = len(surrogate.CONFIG_FEATURES)
    assert np.array_equal(row[:n], surrogate.config_features(cfg))
    assert np.array_equal(row[n:],
                          surrogate.trace_features("blackscholes", 64))


# ----------------------------------------------------------------- training

def test_fit_is_deterministic_in_seed(labeled):
    _, rows = labeled
    m1 = surrogate.fit(rows, steps=150, seed=0)
    m2 = surrogate.fit(rows, steps=150, seed=0)
    m3 = surrogate.fit(rows, steps=150, seed=1)
    for k in m1.params:
        assert np.array_equal(np.asarray(m1.params[k]),
                              np.asarray(m2.params[k])), k
    assert any(not np.array_equal(np.asarray(m1.params[k]),
                                  np.asarray(m3.params[k]))
               for k in m1.params)


def test_fit_rejects_empty_rows():
    with pytest.raises(ValueError, match="at least one"):
        surrogate.fit([])


def test_fit_learns_the_training_set(model, labeled):
    _, rows = labeled
    pred = model.predict_runtime_ns(rows)
    true = np.array([r["runtime_ns"] for r in rows])
    rel = np.abs(pred - true) / true
    assert np.median(rel) < 0.05
    assert model.meta["n_rows"] == 128
    assert model.apps == ("blackscholes", "canneal")


def test_dead_features_stay_bounded_out_of_distribution(model):
    """Knobs the training sweep never varied (phys_regs, l1_kb, ... in
    SPACE_SMOKE) must not blow up predictions when a bigger search space
    sweeps them — the std-floor trap."""
    assert np.all(model.feat_std >= 1e-6)
    cfgs = [eng.VectorEngineConfig(mvl=8, lanes=16, phys_regs=96,
                                   l1_kb=16, interconnect="crossbar",
                                   rob_entries=32, vrf_read_ports=2),
            eng.VectorEngineConfig(mvl=256, lanes=1, l2_kb=2048)]
    pred = model.predict_runtime_ns(
        [{"app": "blackscholes", "cfg": c} for c in cfgs])
    assert np.isfinite(pred).all() and (pred > 0).all()


# ------------------------------------------------------------ batched scorer

def test_space_scorer_matches_row_path_and_exact_area(model):
    scorer = surrogate.SpaceScorer(model, vcfg.SPACE_10K, "blackscholes")
    idx = np.array([0, 1, 255, 4096, 18431])
    pred, area = scorer.score(idx)
    cfgs = [vcfg.SPACE_10K.config_at(int(i)) for i in idx]
    want = model.predict_runtime_ns(
        [{"app": "blackscholes", "cfg": c} for c in cfgs])
    np.testing.assert_allclose(pred, want, rtol=1e-6)
    # the area channel is dse.area_proxy_kb exactly (it gates real resims)
    np.testing.assert_allclose(
        area, [dse.area_proxy_kb(c) for c in cfgs], rtol=1e-6)


def test_space_scorer_handles_spaces_without_mvl_axis(model):
    sp = dse.DesignSpace.of("nomvl", lanes=(2, 8), l2_kb=(256, 1024))
    scorer = surrogate.SpaceScorer(model, sp, "canneal")
    pred, area = scorer.score(np.arange(sp.size()))
    assert pred.shape == (4,) and np.isfinite(pred).all()
    np.testing.assert_allclose(
        area, [dse.area_proxy_kb(c) for c in sp.configs()], rtol=1e-6)


def test_space_scorer_is_deterministic_across_batches(model):
    scorer = surrogate.SpaceScorer(model, vcfg.SPACE_10K, "canneal")
    full, _ = scorer.score(np.arange(2048))
    # a partial (padded) batch scores identically to the same points inside
    # a larger call
    part, _ = scorer.score(np.arange(100, 200))
    assert np.array_equal(part, full[100:200])


# ---------------------------------------------------------------- scorecard

def test_ranks_and_spearman_tie_handling():
    assert surrogate._ranks([10.0, 20.0, 20.0, 30.0]).tolist() == \
        [0.0, 1.5, 1.5, 3.0]
    assert surrogate.spearman([1, 2, 3], [1, 2, 3]) == 1.0
    assert surrogate.spearman([1, 2, 3], [3, 2, 1]) == -1.0
    assert surrogate.spearman([1.0, 1.0], [2.0, 2.0]) == 0.0  # degenerate


def test_scorecard_shape_and_holdout(model, labeled):
    _, rows = labeled
    card = surrogate.scorecard(model, rows, holdout_app="canneal")
    assert card["n_rows"] == 128
    assert 0.0 <= card["rel_err_p50"] <= card["rel_err_p90"] \
        <= card["rel_err_p99"] <= card["rel_err_max"]
    assert set(card["per_app"]) == {"blackscholes", "canneal"}
    assert card["holdout"]["app"] == "canneal"
    assert card["holdout"]["trained_on"] is True
    assert -1.0 <= card["spearman_all"] <= 1.0


def test_scorecard_flags_truly_heldout_app(labeled):
    """Train without canneal: the scorecard must mark it as not trained on —
    the honest-generalization bookkeeping the benchmark rows rely on."""
    _, rows = labeled
    bs_rows = [r for r in rows if r["app"] == "blackscholes"]
    m = surrogate.fit(bs_rows, steps=150, seed=0)
    card = surrogate.scorecard(m, rows, holdout_app="canneal")
    assert m.apps == ("blackscholes",)
    assert card["per_app"]["canneal"]["trained_on"] is False
    assert card["per_app"]["blackscholes"]["trained_on"] is True
    assert np.isfinite(card["holdout"]["mean_rel_err"])
