"""Regression: the count models reproduce the paper's Tables 3-9 and VAO claims."""
import pytest

from repro.core import characterize as ch

DENSE_TOL = 0.011   # <=1.1% on every published cell
CANNEAL_TOL = 0.08  # fitted empirical multipliers; worst cell 7%


@pytest.mark.parametrize("app", list(ch.PAPER_TABLES))
def test_tables_match_paper(app):
    tol = CANNEAL_TOL if app == "canneal" else DENSE_TOL
    for row in ch.compare_to_paper(app):
        for k, v in row.items():
            if k.startswith("err"):
                assert v <= tol, (app, row["mvl"], k, v)


@pytest.mark.parametrize("app,vao", list(ch.PAPER_VAO.items()))
def test_vao_speedups(app, vao):
    got = ch.characterize(app, 8).vao_speedup
    assert abs(got - vao) <= 0.04, (app, got, vao)


def test_blackscholes_pct_vectorization():
    # paper Table 3: 80% / 86% / 87%
    for mvl, pct in [(8, 0.80), (64, 0.86), (256, 0.87)]:
        got = ch.characterize("blackscholes", mvl).pct_vectorization
        assert abs(got - pct) < 0.015, (mvl, got)


def test_swaptions_pct_vectorization():
    # paper Table 9: 81% / 96% / 98%
    for mvl, pct in [(8, 0.81), (64, 0.96), (256, 0.98)]:
        got = ch.characterize("swaptions", mvl).pct_vectorization
        assert abs(got - pct) < 0.015, (mvl, got)


def test_canneal_avg_vl():
    # paper Table 4: average VL 22.25 @64, 65.41 @256
    assert abs(ch.characterize("canneal", 64).avg_vl - 22.25) < 0.7
    assert abs(ch.characterize("canneal", 256).avg_vl - 65.41) < 2.0


def test_pct_vectorization_increases_with_mvl():
    for app in ch.PAPER_TABLES:
        a = ch.characterize(app, 8).pct_vectorization
        b = ch.characterize(app, 256).pct_vectorization
        assert b >= a, app


# --------------------------------------------------------------------------
# ISSUE-8 satellite: unit tests for the §4.1.1 closed forms on synthetic
# Counts — the surrogate's trace features build on these three definitions,
# so they get exact (hand-computable) coverage independent of the app models.
# --------------------------------------------------------------------------

def _synth(scalar_code_total=1000.0, scalar_instrs=200.0, vector_mem=30.0,
           vector_arith=60.0, vector_manip=10.0, vector_ops=800.0):
    from repro.core.tracegen import Counts
    return ch.Characterization(
        "synthetic", 64,
        Counts(scalar_code_total=scalar_code_total,
               scalar_instrs=scalar_instrs, vector_mem=vector_mem,
               vector_arith=vector_arith, vector_manip=vector_manip,
               vector_ops=vector_ops))


def test_pct_vectorization_definition():
    # vector_ops / (scalar_instrs + vector_ops) = 800 / 1000
    assert _synth().pct_vectorization == 0.8
    # no vector work at all -> 0
    assert _synth(vector_ops=0.0).pct_vectorization == 0.0


def test_avg_vl_definition():
    # vector_ops / total_vector_instrs = 800 / (30 + 60 + 10)
    assert _synth().avg_vl == 8.0
    # the max(..., 1) guard: a scalar-only characterization divides by 1,
    # not by zero
    c = _synth(vector_mem=0.0, vector_arith=0.0, vector_manip=0.0,
               vector_ops=0.0)
    assert c.avg_vl == 0.0


def test_vao_speedup_definition():
    # scalar_code_total / (scalar_instrs + vector_ops) = 1000 / 1000
    assert _synth().vao_speedup == 1.0
    # halving the vectorized-code instruction count doubles the VAO speedup
    assert _synth(scalar_instrs=100.0, vector_ops=400.0).vao_speedup == 2.0


def test_row_is_consistent_with_properties():
    c = _synth()
    row = c.row()
    assert row["pct_vectorization"] == c.pct_vectorization
    assert row["average_vl"] == c.avg_vl
    assert row["vao_speedup"] == c.vao_speedup
    assert row["total_vector_instructions"] == 100.0
    assert row["total_instructions"] == 300.0


def test_compare_to_paper_smoke_row():
    """compare_to_paper emits one row per golden MVL with every err_* field
    populated and finite — the smoke row the satellite asks for."""
    rows = ch.compare_to_paper("blackscholes")
    assert [r["mvl"] for r in rows] == [8, 64, 256]
    for r in rows:
        assert r["app"] == "blackscholes"
        for k in ("err_total", "err_scalar", "err_mem", "err_arith",
                  "err_ops"):
            assert 0.0 <= r[k] < 0.02, (r["mvl"], k, r[k])
