"""Regression: the count models reproduce the paper's Tables 3-9 and VAO claims."""
import pytest

from repro.core import characterize as ch

DENSE_TOL = 0.011   # <=1.1% on every published cell
CANNEAL_TOL = 0.08  # fitted empirical multipliers; worst cell 7%


@pytest.mark.parametrize("app", list(ch.PAPER_TABLES))
def test_tables_match_paper(app):
    tol = CANNEAL_TOL if app == "canneal" else DENSE_TOL
    for row in ch.compare_to_paper(app):
        for k, v in row.items():
            if k.startswith("err"):
                assert v <= tol, (app, row["mvl"], k, v)


@pytest.mark.parametrize("app,vao", list(ch.PAPER_VAO.items()))
def test_vao_speedups(app, vao):
    got = ch.characterize(app, 8).vao_speedup
    assert abs(got - vao) <= 0.04, (app, got, vao)


def test_blackscholes_pct_vectorization():
    # paper Table 3: 80% / 86% / 87%
    for mvl, pct in [(8, 0.80), (64, 0.86), (256, 0.87)]:
        got = ch.characterize("blackscholes", mvl).pct_vectorization
        assert abs(got - pct) < 0.015, (mvl, got)


def test_swaptions_pct_vectorization():
    # paper Table 9: 81% / 96% / 98%
    for mvl, pct in [(8, 0.81), (64, 0.96), (256, 0.98)]:
        got = ch.characterize("swaptions", mvl).pct_vectorization
        assert abs(got - pct) < 0.015, (mvl, got)


def test_canneal_avg_vl():
    # paper Table 4: average VL 22.25 @64, 65.41 @256
    assert abs(ch.characterize("canneal", 64).avg_vl - 22.25) < 0.7
    assert abs(ch.characterize("canneal", 256).avg_vl - 65.41) < 2.0


def test_pct_vectorization_increases_with_mvl():
    for app in ch.PAPER_TABLES:
        a = ch.characterize(app, 8).pct_vectorization
        b = ch.characterize(app, 256).pct_vectorization
        assert b >= a, app
