"""Simulation-as-a-service behaviour: hit/cold/coalesce paths, bounded-queue
degradation, Poisson workloads, and the bitwise-equivalence contract against
the batched engine."""
import json
import math

import numpy as np
import pytest

from repro.core import dse
from repro.core import engine as eng
from repro.core import isa, suite, tracegen
from repro.serve.sim_service import (
    SimService, poisson_arrivals, run_workload)

CFG_A = eng.VectorEngineConfig(mvl=64, lanes=4)
CFG_B = eng.VectorEngineConfig(mvl=16, lanes=2, mshrs=1)


# ----------------------------------------------------------- serving paths

def test_cold_path_is_bitwise_the_batched_engine():
    svc = SimService()
    svc.submit("blackscholes", CFG_A)
    svc.submit("canneal", CFG_B)
    svc.drain()
    direct = {}
    for app, cfg in (("blackscholes", CFG_A), ("canneal", CFG_B)):
        body = tracegen.body_for(app, suite.effective_mvl(app, cfg), cfg)
        direct[app] = eng.steady_state_time_batch([body], [cfg])[0]
    by_app = {r.app: r for r in svc.completed}
    assert by_app["blackscholes"].steady_ns == direct["blackscholes"]
    assert by_app["canneal"].steady_ns == direct["canneal"]
    # derived quantities agree with the suite pipeline bitwise
    for app, cfg in (("blackscholes", CFG_A), ("canneal", CFG_B)):
        body = tracegen.body_for(app, suite.effective_mvl(app, cfg), cfg)
        want = suite.vector_runtime_from_per_chunk(app, cfg, body,
                                                   direct[app])
        assert by_app[app].runtime_ns == want
        assert by_app[app].speedup == suite.scalar_runtime_ns(app, cfg) / want


def test_hit_path_answers_without_dispatch_and_bitwise():
    svc = SimService()
    svc.submit("blackscholes", CFG_A)
    svc.drain()
    cold = svc.completed[0]
    n_batches = svc.n_batches
    hit = svc.submit("blackscholes", CFG_A)
    assert hit is not None and hit.source == "cache"
    assert hit.steady_ns == cold.steady_ns
    assert hit.runtime_ns == cold.runtime_ns
    assert svc.n_batches == n_batches           # no dispatch on the hit path


def test_identical_cold_requests_coalesce_into_one_dispatch():
    svc = SimService()
    for _ in range(4):
        svc.submit("blackscholes", CFG_A)
    assert svc.pending_requests() == 4
    svc.drain()
    assert svc.n_dispatched == 1
    assert svc.n_coalesced == 3
    vals = {r.steady_ns for r in svc.completed}
    assert len(vals) == 1                       # all riders, one answer
    sources = sorted(r.source for r in svc.completed)
    assert sources == ["batched", "coalesced", "coalesced", "coalesced"]


def test_mvl_alias_configs_share_a_cell():
    # streamcluster caps at max_vl=128: mvl=128 and mvl=256 produce the same
    # clamped body and timing params, so the second request coalesces onto
    # the first (canneal would NOT alias — its body reads cfg.mvl directly)
    svc = SimService()
    svc.submit("streamcluster", eng.VectorEngineConfig(mvl=128, lanes=4))
    svc.submit("streamcluster", eng.VectorEngineConfig(mvl=256, lanes=4))
    assert svc.pending_requests() == 2
    svc.drain()
    assert svc.n_dispatched == 1 and svc.n_coalesced == 1
    a, b = svc.completed
    assert a.steady_ns == b.steady_ns


def test_asm_variant_and_kernel_trace_requests():
    svc = SimService()
    svc.submit("pathfinder:asm", CFG_A)
    body = tracegen.body_for("blackscholes",
                             suite.effective_mvl("blackscholes", CFG_A),
                             CFG_A)
    svc.submit(body, CFG_A)                     # raw kernel trace
    svc.drain()
    by_src = {r.app: r for r in svc.completed}
    asm = by_src["pathfinder:asm"]
    assert asm.steady_ns > 0 and np.isfinite(asm.runtime_ns)
    (kernel,) = [r for r in svc.completed if r.app.startswith("kernel:")]
    assert kernel.steady_ns > 0
    assert math.isnan(kernel.runtime_ns) and math.isnan(kernel.speedup)
    # the raw trace IS blackscholes' body, so the cells dedup via the key
    hit = svc.submit("blackscholes", CFG_A)
    assert hit is not None and hit.source == "cache"
    assert hit.steady_ns == kernel.steady_ns


def test_batch_fills_trigger_dispatch_without_flush():
    svc = SimService(max_batch=2)
    svc.submit("blackscholes", CFG_A)
    assert svc.n_batches == 0
    svc.submit("canneal", CFG_A)                # fills the batch
    assert svc.n_batches == 1 and svc.pending_requests() == 0
    assert len(svc.completed) == 2


# ----------------------------------------------------- bounded queue limits

def test_bounded_queue_shed_policy():
    svc = SimService(max_queue=2, overflow="shed", max_batch=64)
    apps = ["blackscholes", "canneal", "jacobi-2d", "pathfinder"]
    results = [svc.submit(a, CFG_A) for a in apps]
    assert results[0] is None and results[1] is None
    assert results[2] is not None and results[2].source == "shed"
    assert math.isnan(results[2].steady_ns)
    assert svc.n_shed == 2
    svc.drain()
    assert len(svc.completed) == 2              # shed ones never dispatched
    assert svc.result_for(results[2].uid).source == "shed"


def test_bounded_queue_serialize_policy_never_loses_requests():
    svc = SimService(max_queue=2, overflow="serialize", max_batch=64)
    for a in ["blackscholes", "canneal", "jacobi-2d", "pathfinder"]:
        svc.submit(a, CFG_A)
    svc.drain()
    assert svc.n_shed == 0 and svc.n_serialized >= 1
    assert len(svc.completed) == 4
    assert svc.pending_requests() == 0


# --------------------------------------------------------------- workloads

def test_poisson_arrivals_deterministic_and_sorted():
    cfgs = (CFG_A, CFG_B)
    a = poisson_arrivals(32, 100.0, ("blackscholes", "canneal"), cfgs, seed=3)
    b = poisson_arrivals(32, 100.0, ("blackscholes", "canneal"), cfgs, seed=3)
    assert a == b
    assert [x.t for x in a] == sorted(x.t for x in a)
    assert {x.app for x in a} <= {"blackscholes", "canneal"}
    c = poisson_arrivals(32, 100.0, ("blackscholes", "canneal"), cfgs, seed=4)
    assert a != c


def test_workload_repeat_pass_is_all_hits_and_bitwise(tmp_path):
    path = str(tmp_path / "serve_cache.jsonl")
    cfgs = (CFG_A, CFG_B)
    arrivals = poisson_arrivals(24, 1000.0, ("blackscholes", "canneal"),
                                cfgs, seed=0)
    svc = SimService(cache=dse.ResultCache(path), max_batch=8)
    rep1 = run_workload(svc, arrivals, realtime=False)
    assert rep1.hits == 0 and rep1.dispatched >= 1
    assert rep1.n == 24 and len(rep1.results) == 24

    svc2 = SimService(cache=dse.ResultCache(path), max_batch=8)
    rep2 = run_workload(svc2, arrivals, realtime=False)
    assert rep2.hit_fraction == 1.0 and rep2.dispatched == 0
    r1 = sorted(rep1.results, key=lambda r: r.uid)
    r2 = sorted(rep2.results, key=lambda r: r.uid)
    assert [r.steady_ns for r in r1] == [r.steady_ns for r in r2]
    assert [r.app for r in r1] == [r.app for r in r2]


def test_prewarm_covers_every_service_batch_bucket():
    svc = SimService(max_batch=16)
    assert svc.prewarm() == 2                   # buckets 8 and 16
    jc0 = eng.jit_cache_size()
    arrivals = poisson_arrivals(
        20, 1000.0, ("blackscholes", "canneal"),
        (CFG_A, CFG_B, eng.VectorEngineConfig(mvl=32, lanes=8)), seed=1)
    run_workload(svc, arrivals, realtime=False)
    jc1 = eng.jit_cache_size()
    if jc0 >= 0 and jc1 >= 0:                   # jit introspection available
        assert jc1 == jc0                       # zero steady-state recompiles
    assert svc.recompiles == 0


def test_report_serializes_to_json():
    svc = SimService()
    arrivals = poisson_arrivals(6, 1000.0, ("blackscholes",), (CFG_A,),
                                seed=0)
    rep = run_workload(svc, arrivals, realtime=False)
    d = rep.to_dict()
    json.dumps(d)
    assert d["n"] == 6 and d["hits"] + d["coalesced"] + d["dispatched"] == 6
    assert rep.p99_ms >= rep.p50_ms >= 0.0
    json.dumps(svc.stats())


def test_invalid_service_parameters_rejected():
    with pytest.raises(ValueError):
        SimService(overflow="drop-oldest")
    with pytest.raises(ValueError):
        SimService(max_batch=0)
