"""The analytic memory hierarchy (repro.core.memory) + its live knobs.

Covers the ISSUE acceptance axes: miss-rate monotonicity in the cache sizes,
MSHR saturation for indexed-pattern apps only, the Fig-10 qualitative claim
(bigger LLC helps memory-stressed apps, not compute-bound ones) through the
batched sweep path, and jit-cache stability of the new traced knobs.
"""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import isa, memory, suite, tracegen


# ---------------------------------------------------------------- model unit

def test_miss_probs_monotone_in_l2():
    """P(L2 miss | L1 miss) never increases with LLC capacity."""
    for fp in (64.0, 768.0, 3072.0, 13824.0):
        m2s = [float(memory.miss_probs(fp, 32.0, l2)[1])
               for l2 in (64.0, 128.0, 256.0, 512.0, 1024.0, 4096.0)]
        assert all(a >= b - 1e-7 for a, b in zip(m2s, m2s[1:])), (fp, m2s)


def test_miss_probs_monotone_in_footprint():
    for l2 in (256.0, 1024.0):
        m1s, m2s = zip(*[[float(v) for v in memory.miss_probs(fp, 32.0, l2)]
                         for fp in (8.0, 64.0, 512.0, 4096.0, 65536.0)])
        assert all(a <= b + 1e-7 for a, b in zip(m1s, m1s[1:]))
        assert all(a <= b + 1e-7 for a, b in zip(m2s, m2s[1:]))


def test_miss_probs_edge_cases():
    m1, m2 = memory.miss_probs(0.0, 32.0, 256.0)   # NOP / non-memory entries
    assert float(m1) == 0.0 and float(m2) == 0.0
    m1, m2 = memory.miss_probs(16.0, 32.0, 256.0)  # fits in L1
    assert float(m1) == 0.0
    m1, m2 = memory.miss_probs(1e9, 32.0, 256.0)   # cold stream
    assert float(m1) > 0.99 and float(m2) > 0.99


def test_overlap_gates_indexed_only():
    assert float(memory.overlap(isa.MEM_INDEXED, 1.0)) == 1.0
    assert float(memory.overlap(isa.MEM_INDEXED, 16.0)) == memory.DRAM_MLP
    for pat in (isa.MEM_UNIT, isa.MEM_STRIDED):
        assert float(memory.overlap(pat, 1.0)) == memory.PREFETCH_DEPTH


def test_access_cycles_monotone_in_mshrs():
    """More MSHRs never slow an indexed access down; saturation beyond the
    DRAM bank-parallelism cap."""
    def t(m):
        return float(memory.vector_access_cycles(
            64.0, isa.MEM_INDEXED, 3072.0, 8.0, 32.0, 256.0, float(m),
            4.0, 12.0, 100.0, 16.0, 1.0))
    times = [t(m) for m in (1, 2, 4, 8, 16, 32)]
    assert all(a >= b - 1e-6 for a, b in zip(times, times[1:])), times
    assert times[0] > 3 * times[-1]          # mshrs=1 is a real cliff
    assert times[-2] == times[-1]            # capped at DRAM_MLP


# ------------------------------------------------------------- engine knobs

def _time(app, cfg, tiles=8):
    return eng.simulate(tracegen.body_for(app, cfg.mvl, cfg).tile(tiles),
                        cfg)["time"]


def test_mshr1_degrades_canneal_not_unit_stride_apps():
    """mshrs=1 serializes the indexed netlist walk; unit-stride apps are
    serviced by the decoupled prefetch window and must not move."""
    for app, mvl in (("canneal", 16),):
        base = _time(app, eng.VectorEngineConfig(mvl=mvl, lanes=4))
        m1 = _time(app, eng.VectorEngineConfig(mvl=mvl, lanes=4, mshrs=1))
        assert m1 > 1.2 * base, (app, base, m1)
    for app in ("blackscholes", "jacobi-2d", "swaptions"):
        base = _time(app, eng.VectorEngineConfig(mvl=64, lanes=4))
        m1 = _time(app, eng.VectorEngineConfig(mvl=64, lanes=4, mshrs=1))
        assert abs(m1 - base) <= 1e-3 * base, (app, base, m1)


def test_speedup_monotone_in_l2_for_memory_stressed_apps():
    """Fig-10 qualitative claim via the batched path: growing the LLC
    monotonically helps streamcluster and canneal, and does ~nothing for the
    compute-bound swaptions points (small/mid MVL, working set < 256 KB)."""
    l2s = (256, 512, 1024)
    pairs = [(a, eng.VectorEngineConfig(mvl=64, lanes=4, l2_kb=l2))
             for a in ("streamcluster", "canneal", "swaptions") for l2 in l2s]
    vals = suite.speedup_batch(pairs)
    by_app = {a: vals[i * len(l2s):(i + 1) * len(l2s)]
              for i, a in enumerate(("streamcluster", "canneal", "swaptions"))}
    for app in ("streamcluster", "canneal"):
        s = by_app[app]
        assert s[0] < s[1] < s[2], (app, s)
        assert s[2] > 1.05 * s[0], (app, s)            # a real gain
    s = by_app["swaptions"]
    assert abs(s[2] - s[0]) <= 0.01 * s[0], s          # within noise


def test_swaptions_llc_crossover_at_large_mvl():
    """swaptions IS LLC-sensitive where the paper says it is: the VL-scaled
    HJM working set spills 256 KB at MVL=256 but fits in 1 MB."""
    small = suite.speedup("swaptions",
                          eng.VectorEngineConfig(mvl=256, lanes=8, l2_kb=256))
    big = suite.speedup("swaptions",
                        eng.VectorEngineConfig(mvl=256, lanes=8, l2_kb=1024))
    assert big > 1.1 * small


def test_dram_bandwidth_shared_across_mem_ports():
    """A DRAM-bound stream must not speed up with more L2 ports (the
    bandwidth term is shared); an L2-resident stream must."""
    fp_dram, fp_l2 = 1e6, 128.0
    def t(fp, ports):
        tr = isa.Trace.from_records(
            [isa.vload(256, dst=i, footprint_kb=fp) for i in range(8)])
        return eng.simulate(
            tr, eng.VectorEngineConfig(mvl=256, lanes=8, mem_ports=ports))["time"]
    assert t(fp_dram, 4) >= 0.95 * t(fp_dram, 1)
    assert t(fp_l2, 4) < 0.75 * t(fp_l2, 1)


def test_batched_equals_sequential_on_memory_knobs():
    """Batched-vs-sequential equivalence extended to the new axes: mixed
    l1_kb/l2_kb/mshrs/dram-bw configs in one batch."""
    cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4),
            eng.VectorEngineConfig(mvl=64, lanes=4, l2_kb=1024),
            eng.VectorEngineConfig(mvl=64, lanes=4, mshrs=1),
            eng.VectorEngineConfig(mvl=64, lanes=4, l1_kb=64,
                                   dram_bw_bytes_cycle=8.0)]
    for app in ("canneal", "streamcluster"):
        traces = [tracegen.body_for(app, c.mvl, c).tile(2) for c in cfgs]
        for got, tr, cfg in zip(eng.simulate_batch(traces, cfgs), traces, cfgs):
            want = eng.simulate(tr, cfg)
            for k in want:
                assert abs(got[k] - want[k]) <= 1e-5 * max(abs(want[k]), 1.0)


def test_llc_sweep_reuses_compiled_executable():
    """Repeat LLC/MSHR sweeps must not grow the jit cache: the new knobs are
    traced values, never compile-time constants."""
    pairs = [("canneal", eng.VectorEngineConfig(mvl=16, lanes=2, l2_kb=256))]
    suite.speedup_batch(pairs)
    before = eng.jit_cache_size()
    if before == -1:
        pytest.skip("installed JAX exposes no jit cache introspection")
    pairs = [(a, eng.VectorEngineConfig(mvl=16, lanes=2, l2_kb=l2, mshrs=m))
             for a in ("canneal", "swaptions")
             for l2 in (256, 1024) for m in (1, 16)]
    suite.speedup_batch(pairs)
    assert eng.jit_cache_size() == before


def test_config_labels_distinct_across_memory_knobs():
    """ISSUE satellite: configs differing only in l2_kb/mshrs/interconnect
    must not collide to the same label."""
    cfgs = [eng.VectorEngineConfig(mvl=256, lanes=8),
            eng.VectorEngineConfig(mvl=256, lanes=8, l2_kb=1024),
            eng.VectorEngineConfig(mvl=256, lanes=8, mshrs=1),
            eng.VectorEngineConfig(mvl=256, lanes=8, l1_kb=64),
            eng.VectorEngineConfig(mvl=256, lanes=8, dram_bw_bytes_cycle=8.0),
            eng.VectorEngineConfig(mvl=256, lanes=8, interconnect="crossbar"),
            eng.VectorEngineConfig(mvl=256, lanes=8, ooo_issue=True)]
    labels = [c.label() for c in cfgs]
    assert len(set(labels)) == len(labels), labels
    assert labels[0] == "mvl256_l8"          # Table-10 defaults keep old keys


def test_table10_variant_grids():
    from repro.configs import vector_engine as ve
    assert len(ve.TABLE10_L2_1MB) == len(ve.TABLE10_MSHR1) == 24
    assert all(c.l2_kb == 1024 for c in ve.TABLE10_L2_1MB)
    assert all(c.mshrs == 1 for c in ve.TABLE10_MSHR1)
    labels = {c.label() for c in
              ve.TABLE10 + ve.TABLE10_L2_1MB + ve.TABLE10_MSHR1}
    assert len(labels) == 72
