"""Batched-vs-sequential equivalence and padding invariance for the engine.

The batched path shares the sequential scan step (flags are traced, padding
is timing-neutral), so agreement is expected to be bitwise; the asserts allow
1e-5 relative slack for XLA fusion differences, far inside the 1e-3 the
reproduction tolerates.
"""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import isa, tracegen

APPS = sorted(tracegen.APPS)
TABLE10_GRID = [(m, l) for m in (8, 16, 32, 64, 128, 256)
                for l in (1, 2, 4, 8)]


def _close(a, b, tol=1e-5):
    assert abs(a - b) <= tol * max(abs(b), 1.0), (a, b)


def test_batch_matches_sequential_on_table10_grid():
    """Every Table-10 config x every app: simulate_batch == simulate."""
    pairs = [(app, eng.VectorEngineConfig(mvl=m, lanes=l))
             for app in APPS for m, l in TABLE10_GRID]
    traces = [tracegen.body_for(a, c.mvl, c).tile(2) for a, c in pairs]
    cfgs = [c for _, c in pairs]
    batched = eng.simulate_batch(traces, cfgs)
    for (app, cfg), tr, got in zip(pairs, traces, batched):
        want = eng.simulate(tr, cfg)
        for k in want:
            _close(got[k], want[k])


@pytest.mark.parametrize("ooo", [False, True])
@pytest.mark.parametrize("ic", ["ring", "crossbar"])
@pytest.mark.parametrize("l2_kb,mshrs", [(256, 16), (1024, 1)])
def test_batch_matches_sequential_flag_grid(ooo, ic, l2_kb, mshrs):
    """The formerly-static ooo/interconnect flags — and the memory-hierarchy
    knobs the analytic model made live — are traced selects and still
    produce sequential-identical results in a mixed batch."""
    cfgs = [eng.VectorEngineConfig(mvl=m, lanes=l, ooo_issue=ooo,
                                   interconnect=ic, l2_kb=l2_kb, mshrs=mshrs)
            for m, l in ((8, 1), (64, 4), (256, 8))]
    body = tracegen.body_for("jacobi-2d", 64, cfgs[0])
    recs = [isa.vreduce(128, src1=1, dst=2), isa.vslide(128, src1=2, dst=3)]
    tr = body.concat(isa.Trace.from_records(recs)).tile(3)
    for got, cfg in zip(eng.simulate_batch([tr], cfgs), cfgs):
        want = eng.simulate(tr, cfg)
        for k in want:
            _close(got[k], want[k])


def test_batch_broadcasts_and_preserves_order():
    cfg = eng.VectorEngineConfig(mvl=64, lanes=2)
    bodies = [tracegen.body_for(a, 64, cfg).tile(2)
              for a in ("blackscholes", "pathfinder", "streamcluster")]
    got = eng.simulate_batch(bodies, [cfg])
    for tr, row in zip(bodies, got):
        assert row["time"] == eng.simulate(tr, cfg)["time"]


def test_padding_invariance_exact():
    """Appending NOPs never changes any reported metric, bitwise."""
    for app, mvl in (("blackscholes", 64), ("canneal", 16),
                     ("particlefilter", 256)):
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
        tr = tracegen.body_for(app, mvl, cfg).tile(2)
        base = eng.simulate(tr, cfg)
        for extra in (1, 17, 256):
            padded = eng.simulate(tr.pad_to(len(tr) + extra), cfg)
            assert padded == base, (app, extra)


def test_nop_trace_is_timing_neutral_alone():
    cfg = eng.VectorEngineConfig()
    out = eng.simulate(isa.nop_trace(64), cfg)
    assert out["time"] == 0.0 and out["lane_busy"] == 0.0


def test_pad_to_validates_and_roundtrips():
    tr = isa.Trace.from_records([isa.varith(8), isa.nop()])
    assert len(tr.pad_to(10)) == 10
    assert tr.pad_to(2) is tr
    with pytest.raises(ValueError):
        tr.pad_to(1)
    stacked = isa.stack_traces([tr, tr.pad_to(5)])
    assert stacked.kind.shape == (2, 5)


def test_steady_state_batch_matches_sequential():
    """The fused warmup-checkpoint scan equals the two-simulation recipe."""
    pairs = [("blackscholes", eng.VectorEngineConfig(mvl=64, lanes=4)),
             ("jacobi-2d", eng.VectorEngineConfig(mvl=256, lanes=8,
                                                  ooo_issue=True)),
             ("streamcluster", eng.VectorEngineConfig(mvl=8, lanes=1)),
             ("canneal", eng.VectorEngineConfig(mvl=16, lanes=2,
                                                interconnect="crossbar"))]
    bodies = [tracegen.body_for(a, c.mvl, c) for a, c in pairs]
    cfgs = [c for _, c in pairs]
    got = eng.steady_state_time_batch(bodies, cfgs, warmup=4, measure=8)
    for (app, cfg), body, g in zip(pairs, bodies, got):
        want = eng.steady_state_time(body, cfg, warmup=4, measure=8)
        _close(g, want)


def test_batch_reuses_compiled_executable():
    """Compilation is keyed on (batch bucket, CHUNK): new trace lengths and
    new flag combinations must NOT trigger a recompile."""
    cfg_a = eng.VectorEngineConfig(mvl=64, lanes=4)
    tr = tracegen.body_for("pathfinder", 64, cfg_a).tile(2)
    eng.simulate_batch([tr], [cfg_a, cfg_a])
    before = eng.jit_cache_size()
    if before == -1:
        pytest.skip("installed JAX exposes no jit cache introspection")
    longer = tr.tile(3)  # different length, same bucket arithmetic shape
    other = eng.VectorEngineConfig(mvl=128, lanes=8, ooo_issue=True,
                                   interconnect="crossbar")
    eng.simulate_batch([longer], [other, other])
    assert eng.jit_cache_size() == before


def test_empty_batches_return_empty():
    assert eng.simulate_batch([], []) == []
    assert eng.steady_state_time_batch([], []) == []


def test_single_trace_broadcasts_against_many_configs():
    cfg_grid = [eng.VectorEngineConfig(mvl=m, lanes=l)
                for m in (8, 64, 256) for l in (1, 8)]
    tr = tracegen.body_for("swaptions", 64, cfg_grid[0]).tile(2)
    rows = eng.simulate_batch([tr], cfg_grid)
    times = eng.steady_state_time_batch([tracegen.body_for("swaptions", 64,
                                                           cfg_grid[0])],
                                        cfg_grid, warmup=4, measure=8)
    assert len(rows) == len(times) == len(cfg_grid)
    for cfg, row, t in zip(cfg_grid, rows, times):
        want = eng.simulate(tr, cfg)
        for k in want:
            _close(row[k], want[k])
        _close(t, eng.steady_state_time(
            tracegen.body_for("swaptions", 64, cfg_grid[0]), cfg,
            warmup=4, measure=8))


def test_mixed_length_bucket_batch_matches_sequential():
    """Traces landing in different CHUNK buckets run as separate groups but
    must come back in input order, equal to sequential simulate."""
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    short = tracegen.body_for("pathfinder", 64, cfg)          # ~16 instrs
    mid = tracegen.body_for("blackscholes", 64, cfg).tile(4)  # ~1.2k
    long = tracegen.body_for("particlefilter", 64, cfg).tile(3)  # ~2.8k
    traces = [mid, short, long, short.tile(2)]
    buckets = {eng._len_bucket(len(t)) for t in traces}
    assert len(buckets) >= 2          # the premise: a genuinely mixed batch
    rows = eng.simulate_batch(traces, [cfg] * len(traces))
    for tr, row in zip(traces, rows):
        want = eng.simulate(tr, cfg)
        for k in want:
            _close(row[k], want[k])
